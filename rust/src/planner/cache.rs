//! Schedule-lowering cache: snapped spec → `Arc<ScheduleProgram>`.
//!
//! `simloop::lower_plan` snaps a planner configuration to an executable
//! schedule shape before lowering it, and *many* candidate configurations
//! collapse to the same snapped shape (the snap quantises n_l to a
//! divisor of d_l and n_μ to at least n_l, and the generator ignores
//! n_b and b_μ entirely — those only price the cost table; the
//! tensor-parallel degree changes the schedule, so it keys the cache).
//! Re-lowering
//! the identical schedule for every candidate made `rank_by_simulation`
//! O(candidates × lowering); this memo makes it O(distinct shapes ×
//! lowering + candidates × simulation).
//!
//! The cache is keyed by ([`PolicyKind`], the [`ScheduleSpec`] fields) and
//! hands out `Arc`s, so concurrent ranking threads share one immutable
//! program. Misses lower outside the lock — racing builders are
//! idempotent and the first insert wins.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::collective::Topology;
use crate::costmodel::Strategy;
use crate::schedule::{
    decode_wave, layered_ga, lower, modular_pipeline, prefill_pipeline, standard_ga, Schedule,
    ScheduleProgram, ScheduleSpec,
};

/// Which generator a planner configuration executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Standard gradient accumulation / contiguous pipeline (baseline).
    StandardGa,
    /// Layered gradient accumulation, single stage.
    LayeredGa,
    /// Layered accumulation over the modular pipeline split.
    ModularPipeline,
    /// Forward-only serving prefill (n_mu = in-flight requests, one
    /// prompt per micro-batch slot).
    ServePrefill,
    /// Forward-only serving decode: one wave, every in-flight request
    /// advances one token.
    ServeDecode,
}

impl PolicyKind {
    /// The generator a snapped planner config runs: baseline plans run
    /// standard GA / the contiguous pipeline; improved and partitioned
    /// plans run layered accumulation (modular pipeline when staged).
    pub fn for_config(strategy: Strategy, n_l: usize) -> PolicyKind {
        match (strategy, n_l) {
            (Strategy::Baseline, _) => PolicyKind::StandardGa,
            (_, 1) => PolicyKind::LayeredGa,
            (_, _) => PolicyKind::ModularPipeline,
        }
    }

    /// Generate the schedule this policy emits for a spec.
    pub fn generate(self, spec: &ScheduleSpec) -> Schedule {
        match self {
            PolicyKind::StandardGa => standard_ga(spec),
            PolicyKind::LayeredGa => layered_ga(spec),
            PolicyKind::ModularPipeline => modular_pipeline(spec),
            PolicyKind::ServePrefill => prefill_pipeline(spec),
            PolicyKind::ServeDecode => decode_wave(spec),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Key {
    kind: PolicyKind,
    d_l: usize,
    n_l: usize,
    n_mu: usize,
    /// Whether the schedule carries `TensorAllReduce` ops. Generators
    /// branch only on `tp > 1` — every tp > 1 degree yields the same op
    /// arena and edges, so keying on the exact value would re-lower an
    /// identical program once per n_a candidate. The cached program's
    /// `tp` metadata field may therefore record a different tp > 1
    /// degree than the request; the planner only executes the ops and
    /// prices them through its own `CostTable`, which carries the real
    /// n_a.
    tensor_parallel: bool,
    partition: bool,
    offload: bool,
    data_parallel: bool,
    /// ZeRO stage: stages emit different op shapes (≥2 swaps the
    /// reduce, 1–2 vs 3 place the gathers differently), so each keys
    /// its own program.
    zero: u8,
}

impl Key {
    fn new(kind: PolicyKind, spec: &ScheduleSpec) -> Key {
        Key {
            kind,
            d_l: spec.d_l,
            n_l: spec.n_l,
            n_mu: spec.n_mu,
            tensor_parallel: spec.tp > 1,
            partition: spec.partition,
            offload: spec.offload,
            data_parallel: spec.data_parallel,
            zero: spec.zero,
        }
    }
}

/// Generational size cap: past this many distinct shapes the map is
/// cleared wholesale (the planner's working set per sweep is far
/// smaller; the cap only bounds pathological long-running processes).
const MAX_ENTRIES: usize = 512;

/// Memo of lowered schedule programs. Use [`LoweringCache::global`] for
/// the process-wide instance the planner shares, or construct a local
/// one for isolation (tests, one-shot tools).
#[derive(Debug, Default)]
pub struct LoweringCache {
    map: Mutex<HashMap<Key, Arc<ScheduleProgram>>>,
    /// Whole-world structural verdicts ([`crate::analysis`]) for the
    /// same snapped shapes. The structural checks are topology-shape
    /// invariant (dp/tp clamp to ≤ 2 inside the verifier), so a verdict
    /// is as cacheable as the lowering itself — the planner's static
    /// filter costs one hash lookup per candidate after the first.
    verdicts: Mutex<HashMap<Key, Result<(), String>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl LoweringCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide cache.
    pub fn global() -> &'static LoweringCache {
        static GLOBAL: OnceLock<LoweringCache> = OnceLock::new();
        GLOBAL.get_or_init(LoweringCache::new)
    }

    /// Generate + lower `spec` under `kind`, or return the memoised
    /// program. Panics only if a generated schedule fails to lower —
    /// generators produce lowerable schedules by construction.
    pub fn lower(&self, kind: PolicyKind, spec: &ScheduleSpec) -> Arc<ScheduleProgram> {
        let key = Key::new(kind, spec);
        if let Some(hit) = self.map.lock().expect("lowering cache poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(hit);
        }
        // Miss: generate + lower outside the lock (it can be many
        // milliseconds for deep programs). Racing threads may build the
        // same program; the first insert wins and the losers drop theirs.
        let program = Arc::new(
            lower(&kind.generate(spec)).expect("generated schedules always lower"),
        );
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().expect("lowering cache poisoned");
        if map.len() >= MAX_ENTRIES {
            map.clear();
        }
        Arc::clone(map.entry(key).or_insert(program))
    }

    /// Whole-world structural verification
    /// ([`crate::analysis::verify_structural`]) of the program `spec`
    /// lowers to, memoised under the same key as the lowering. The
    /// replicated-axis degrees only matter up to "is the axis on" —
    /// exactly the information [`Key`] already captures — so the
    /// verdict for dp/tp degree 2 answers for every higher degree.
    pub fn verify_structural(&self, kind: PolicyKind, spec: &ScheduleSpec) -> Result<(), String> {
        let key = Key::new(kind, spec);
        if let Some(v) = self.verdicts.lock().expect("verdict cache poisoned").get(&key) {
            return v.clone();
        }
        // Miss: verify outside the lock (the lowering itself may also
        // miss and lower). Racing verifiers agree — first insert wins.
        let program = self.lower(kind, spec);
        let topo = Topology::new(
            program.n_stages,
            if spec.data_parallel { 2 } else { 1 },
            if spec.tp > 1 { 2 } else { 1 },
        );
        let verdict =
            crate::analysis::verify_structural(&program, topo).map_err(|e| e.to_string());
        let mut verdicts = self.verdicts.lock().expect("verdict cache poisoned");
        if verdicts.len() >= MAX_ENTRIES {
            verdicts.clear();
        }
        verdicts.entry(key).or_insert_with(|| verdict.clone());
        verdict
    }

    /// Cache hits so far (lifetime of this cache instance).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (= lowerings actually performed).
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct programs currently held.
    pub fn len(&self) -> usize {
        self.map.lock().expect("lowering cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n_l: usize, n_mu: usize) -> ScheduleSpec {
        ScheduleSpec {
            d_l: 16,
            n_l,
            n_mu,
            tp: 1,
            partition: true,
            offload: false,
            data_parallel: true,
            zero: 0,
        }
    }

    #[test]
    fn identical_specs_share_one_program() {
        let cache = LoweringCache::new();
        let a = cache.lower(PolicyKind::ModularPipeline, &spec(4, 8));
        let b = cache.lower(PolicyKind::ModularPipeline, &spec(4, 8));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_policies_and_shapes_get_distinct_programs() {
        let cache = LoweringCache::new();
        let a = cache.lower(PolicyKind::ModularPipeline, &spec(4, 8));
        let b = cache.lower(PolicyKind::StandardGa, &spec(4, 8));
        let c = cache.lower(PolicyKind::ModularPipeline, &spec(4, 16));
        // Offload changes the emitted ops — it must key separately.
        let mut off = spec(4, 8);
        off.offload = true;
        let d = cache.lower(PolicyKind::ModularPipeline, &off);
        // So does turning tensor parallelism on (TensorAllReduce ops).
        let mut tp = spec(4, 8);
        tp.tp = 2;
        let e = cache.lower(PolicyKind::ModularPipeline, &tp);
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert!(!Arc::ptr_eq(&a, &d));
        assert!(!Arc::ptr_eq(&a, &e));
        assert!(d.offloaded && !a.offloaded);
        assert_eq!(e.tp, 2);
        assert!(e.len() > a.len(), "tp program carries the TensorAllReduce ops");
        assert_eq!(cache.misses(), 5);
        assert_eq!(cache.len(), 5);
        // The exact tp *degree* does not change the op shape — tp = 4
        // must hit the tp = 2 entry instead of re-lowering (the planner
        // prices n_a through its CostTable, not the program).
        tp.tp = 4;
        let f = cache.lower(PolicyKind::ModularPipeline, &tp);
        assert!(Arc::ptr_eq(&e, &f));
        assert_eq!(cache.misses(), 5);
    }

    #[test]
    fn cached_program_matches_a_fresh_lowering() {
        let cache = LoweringCache::new();
        let cached = cache.lower(PolicyKind::LayeredGa, &spec(1, 8));
        let fresh = lower(&layered_ga(&spec(1, 8))).unwrap();
        assert_eq!(cached.len(), fresh.len());
        assert_eq!(cached.n_edges(), fresh.n_edges());
        assert_eq!(cached.name, fresh.name);
    }

    #[test]
    fn policy_kind_follows_strategy_and_stage_count() {
        assert_eq!(PolicyKind::for_config(Strategy::Baseline, 4), PolicyKind::StandardGa);
        assert_eq!(PolicyKind::for_config(Strategy::Baseline, 1), PolicyKind::StandardGa);
        assert_eq!(PolicyKind::for_config(Strategy::Improved, 1), PolicyKind::LayeredGa);
        assert_eq!(PolicyKind::for_config(Strategy::Partitioned, 1), PolicyKind::LayeredGa);
        assert_eq!(PolicyKind::for_config(Strategy::Improved, 4), PolicyKind::ModularPipeline);
    }
}
