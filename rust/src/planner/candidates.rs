//! Candidate enumeration for the planner's grid search.
//!
//! The pre-refactor `search_fastest` interleaved enumeration and
//! evaluation in six nested loops. This module factors the enumeration
//! out as a lazy iterator over the (n_a, n_l, n_μ, b_μ, offload,
//! partition) grid that yields candidates in the *exact order* the old
//! loops visited them (the parity tests rely on this), applying only the
//! cheap structural filters on the way:
//!
//! * the §5 rule that the partitioned strategy forgoes pipelining (whole
//!   n_l rows skipped without materialising their grid points);
//! * the critical-batch budget — a data-parallel degree is derived from
//!   b_c and candidates overshooting the budget are dropped;
//! * `TrainConfig::validate` consistency.
//!
//! Everything expensive — the memory breakdown, the full cost-model
//! estimate — happens downstream in `search.rs`, where it can be
//! pre-filtered (memory lower bound), branch-and-bound pruned
//! ([`optimistic_secs`]) and fanned out across threads.

use crate::costmodel::{ParallelismMenu, Strategy, TrainConfig};
use crate::hardware::ClusterSpec;
use crate::model::{XModel, TRAINING_STEPS};

use super::rules::max_tensor_parallel;

/// Candidate micro-batch sizes tried by the search.
pub(crate) const B_MU_CANDIDATES: [f64; 7] = [1.0, 2.0, 4.0, 5.0, 8.0, 16.0, 32.0];

/// Multipliers applied to max(n_l, 1) to get the micro-batch count.
pub(crate) const N_MU_FACTORS: [f64; 8] = [1.0, 1.05, 1.1, 1.25, 1.5, 2.0, 4.0, 8.0];

/// Lazy, ordered enumeration of the search grid for one
/// (strategy, menu) pair on a cluster.
pub struct Candidates {
    strategy: Strategy,
    menu: ParallelismMenu,
    /// Critical batch size b_c (the batch budget).
    bc: f64,
    n_a: Vec<usize>,
    n_l: Vec<usize>,
    /// (offload, partition) pairs in legacy order: offload outer,
    /// strategy-dependent partition list inner.
    variants: Vec<(bool, bool)>,
    /// n_μ candidates for the current (n_l, factor) point.
    extra: Vec<usize>,
    // Odometer indices, outermost to innermost.
    ia: usize,
    il: usize,
    ifac: usize,
    iex: usize,
    ibmu: usize,
    ivar: usize,
    done: bool,
}

impl Candidates {
    pub fn new(
        model: &XModel,
        cluster: &ClusterSpec,
        strategy: Strategy,
        menu: ParallelismMenu,
    ) -> Self {
        let shape = model.shape();
        let d_l = shape.d_l;
        let bc = model.critical_batch_size();

        let n_a_max = if menu.tensor { max_tensor_parallel(model, cluster) } else { 1 };
        let n_a = {
            let mut v = vec![1usize, 2, 4, 8, 16, 32, 64, 128];
            v.retain(|&a| a <= n_a_max);
            if !v.contains(&n_a_max) {
                v.push(n_a_max);
            }
            v
        };

        let n_l = if menu.pipeline {
            let mut v: Vec<usize> = [
                1usize, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 40, 48, 64, 80, 96, 128, 160,
                192, 256,
            ]
            .iter()
            .copied()
            .filter(|&l| l <= d_l)
            .collect();
            if !v.contains(&d_l) {
                v.push(d_l);
            }
            v
        } else {
            vec![1]
        };

        let partitions: &[bool] = match strategy {
            Strategy::Baseline => &[false],
            Strategy::Partitioned => &[true],
            // §8.3: for small models the improved method may skip the
            // partition for extra speed.
            Strategy::Improved => &[true, false],
        };
        let variants: Vec<(bool, bool)> = [false, true]
            .into_iter()
            .flat_map(|o| partitions.iter().map(move |&p| (o, p)))
            .collect();

        let done = n_a.is_empty() || n_l.is_empty();
        let extra = if done { Vec::new() } else { extra_n_mu(n_l[0], N_MU_FACTORS[0]) };
        Candidates {
            strategy,
            menu,
            bc,
            n_a,
            n_l,
            variants,
            extra,
            ia: 0,
            il: 0,
            ifac: 0,
            iex: 0,
            ibmu: 0,
            ivar: 0,
            done,
        }
    }

    /// Build the config at the current grid point, or `None` when the
    /// structural filters reject it.
    fn current(&self) -> Option<TrainConfig> {
        let n_a = self.n_a[self.ia];
        let n_l = self.n_l[self.il];
        let n_mu = self.extra[self.iex];
        let b_mu = B_MU_CANDIDATES[self.ibmu];
        let (offload, partition) = self.variants[self.ivar];
        // Derive the data-parallel degree from the critical-batch budget.
        let n_b = if self.menu.data {
            ((self.bc / (n_mu as f64 * b_mu)).floor() as usize).max(1)
        } else {
            1
        };
        if self.menu.data && (n_b as f64) * (n_mu as f64) * b_mu > self.bc * 1.001 {
            return None; // overshoots the batch budget
        }
        let cfg = TrainConfig {
            strategy: self.strategy,
            n_b,
            n_l,
            n_a,
            n_mu,
            b_mu,
            offload,
            partition,
            // The ZeRO axis enters the search through
            // `search_fastest_zero`, which rewrites the enumerated grid
            // — enumerating it here would break the frozen legacy order.
            zero: 0,
        };
        cfg.validate().ok()?;
        Some(cfg)
    }

    /// Advance the odometer one grid point (innermost index first).
    fn advance(&mut self) {
        self.ivar += 1;
        if self.ivar < self.variants.len() {
            return;
        }
        self.ivar = 0;
        self.ibmu += 1;
        if self.ibmu < B_MU_CANDIDATES.len() {
            return;
        }
        self.ibmu = 0;
        self.iex += 1;
        if self.iex < self.extra.len() {
            return;
        }
        self.iex = 0;
        self.ifac += 1;
        if self.ifac < N_MU_FACTORS.len() {
            self.refresh_extra();
            return;
        }
        self.ifac = 0;
        self.bump_n_l();
    }

    /// Move to the next n_l row (resetting every inner index).
    fn bump_n_l(&mut self) {
        self.ivar = 0;
        self.ibmu = 0;
        self.iex = 0;
        self.ifac = 0;
        self.il += 1;
        if self.il >= self.n_l.len() {
            self.il = 0;
            self.ia += 1;
            if self.ia >= self.n_a.len() {
                self.done = true;
                return;
            }
        }
        self.refresh_extra();
    }

    fn refresh_extra(&mut self) {
        self.extra = extra_n_mu(self.n_l[self.il], N_MU_FACTORS[self.ifac]);
    }
}

/// The n_μ candidates for one (n_l, factor) point: the factored count,
/// plus large plain gradient-accumulation depths when there is no
/// pipeline.
fn extra_n_mu(n_l: usize, factor: f64) -> Vec<usize> {
    let n_mu_base = ((n_l as f64 * factor).round() as usize).max(1);
    if n_l == 1 {
        vec![n_mu_base, 2, 8, 32, 128, 512]
    } else {
        vec![n_mu_base]
    }
}

impl Iterator for Candidates {
    type Item = TrainConfig;

    fn next(&mut self) -> Option<TrainConfig> {
        while !self.done {
            // §5: the partitioned approach forgoes pipelining — skip the
            // whole n_l row in one step.
            if self.strategy == Strategy::Partitioned && self.n_l[self.il] > 1 {
                self.bump_n_l();
                continue;
            }
            let candidate = self.current();
            self.advance();
            if let Some(cfg) = candidate {
                return Some(cfg);
            }
        }
        None
    }
}

/// Compute-only lower bound on a candidate's training time: the total
/// training flops at perfect efficiency on the candidate's GPU count.
/// `costmodel::estimate` divides the same flops by
/// (n_gpu · peak · efficiency) with efficiency ≤ 1 (every overhead term
/// is non-negative), so this bound can never exceed the real estimate —
/// which is what makes the branch-and-bound cutoff in `search.rs` safe.
pub(crate) fn optimistic_secs(model: &XModel, cfg: &TrainConfig, cluster: &ClusterSpec) -> f64 {
    let b_eff = cfg.batch_size().max(model.critical_batch_size());
    model.training_flops(b_eff, TRAINING_STEPS) / (cfg.n_gpu() as f64 * cluster.gpu.peak_flops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::estimate;

    /// Literal transcription of the pre-refactor nested loops, kept as a
    /// fixture: the iterator must reproduce this sequence exactly.
    fn legacy_order(
        model: &XModel,
        cluster: &ClusterSpec,
        strategy: Strategy,
        menu: ParallelismMenu,
    ) -> Vec<TrainConfig> {
        let shape = model.shape();
        let d_l = shape.d_l;
        let bc = model.critical_batch_size();
        let n_a_max = if menu.tensor { max_tensor_parallel(model, cluster) } else { 1 };
        let n_a_candidates: Vec<usize> = {
            let mut v = vec![1usize, 2, 4, 8, 16, 32, 64, 128];
            v.retain(|&a| a <= n_a_max);
            if !v.contains(&n_a_max) {
                v.push(n_a_max);
            }
            v
        };
        let n_l_candidates: Vec<usize> = if menu.pipeline {
            let mut v: Vec<usize> = [
                1usize, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 40, 48, 64, 80, 96, 128, 160,
                192, 256,
            ]
            .iter()
            .copied()
            .filter(|&l| l <= d_l)
            .collect();
            if !v.contains(&d_l) {
                v.push(d_l);
            }
            v
        } else {
            vec![1]
        };
        let mut out = Vec::new();
        for &n_a in &n_a_candidates {
            for &n_l in &n_l_candidates {
                if strategy == Strategy::Partitioned && n_l > 1 {
                    continue;
                }
                for &f in &N_MU_FACTORS {
                    let n_mu_base = ((n_l as f64 * f).round() as usize).max(1);
                    let extra: Vec<usize> = if n_l == 1 {
                        vec![n_mu_base, 2, 8, 32, 128, 512]
                    } else {
                        vec![n_mu_base]
                    };
                    for n_mu in extra {
                        for &b_mu in &B_MU_CANDIDATES {
                            let n_b = if menu.data {
                                ((bc / (n_mu as f64 * b_mu)).floor() as usize).max(1)
                            } else {
                                1
                            };
                            if (n_b as f64) * (n_mu as f64) * b_mu > bc * 1.001 && menu.data {
                                continue;
                            }
                            let partitions: &[bool] = match strategy {
                                Strategy::Baseline => &[false],
                                Strategy::Partitioned => &[true],
                                Strategy::Improved => &[true, false],
                            };
                            for (offload, &partition) in [false, true]
                                .into_iter()
                                .flat_map(|o| partitions.iter().map(move |p| (o, p)))
                            {
                                let cfg = TrainConfig {
                                    strategy,
                                    n_b,
                                    n_l,
                                    n_a,
                                    n_mu,
                                    b_mu,
                                    offload,
                                    partition,
                                    zero: 0,
                                };
                                if cfg.validate().is_err() {
                                    continue;
                                }
                                out.push(cfg);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn iterator_reproduces_the_legacy_loop_order() {
        let cluster = ClusterSpec::reference();
        for model in [XModel::new(16), XModel::new(64)] {
            for strategy in Strategy::ALL {
                for menu in [
                    ParallelismMenu::THREE_D,
                    ParallelismMenu::DATA,
                    ParallelismMenu::DATA_PIPE,
                    ParallelismMenu::NONE,
                ] {
                    let lazy: Vec<TrainConfig> =
                        Candidates::new(&model, &cluster, strategy, menu).collect();
                    let legacy = legacy_order(&model, &cluster, strategy, menu);
                    assert_eq!(
                        lazy, legacy,
                        "order diverged for {strategy:?}/{menu:?} at X_{}",
                        model.x
                    );
                }
            }
        }
    }

    #[test]
    fn every_candidate_is_valid_and_within_budget() {
        let cluster = ClusterSpec::ethernet();
        let model = XModel::new(32);
        let bc = model.critical_batch_size();
        let mut count = 0usize;
        for cfg in Candidates::new(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D)
        {
            cfg.validate().unwrap();
            assert!(cfg.batch_size() <= bc * 1.001, "{cfg:?} overshoots b_c");
            count += 1;
        }
        assert!(count > 1000, "grid unexpectedly small: {count}");
    }

    #[test]
    fn optimistic_bound_never_exceeds_the_estimate() {
        let cluster = ClusterSpec::reference();
        let model = XModel::new(64);
        for cfg in
            Candidates::new(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D)
                .step_by(17)
        {
            let lower = optimistic_secs(&model, &cfg, &cluster);
            let real = estimate(&model, &cfg, &cluster).training_secs;
            assert!(
                lower <= real * (1.0 + 1e-12),
                "bound {lower} above estimate {real} for {cfg:?}"
            );
        }
    }
}
