//! SLO-driven serving planner: pick `{stages, tp, max batch}` to
//! maximise throughput subject to a latency SLO.
//!
//! The training planner optimises step time; serving optimises
//! tokens/sec *under a constraint* — here p99 time-to-first-token.
//! The search enumerates the deployment grid the same way
//! `candidates.rs` enumerates training configurations (structural
//! filters first, expensive evaluation after), reuses the global
//! [`LoweringCache`] through [`ServeCosts`], statically verifies every
//! candidate's prefill/decode programs (whole-world compose at dp = 1,
//! KV-aware memory bound), and replays one seeded request trace
//! through the continuous batcher per surviving candidate. Feasible
//! candidates are ranked by measured tokens/sec; if none meets the
//! SLO, the closest miss is returned with a diagnostic naming the
//! binding constraint (SLO, KV admission, or memory).

use crate::analysis::{verify_program, MemoryModel};
use crate::collective::Topology;
use crate::costmodel::KvCacheModel;
use crate::hardware::ClusterSpec;
use crate::model::TransformerShape;
use crate::runtime::DType;
use crate::schedule::ScheduleSpec;
use crate::serve::{run_trace, ServeCosts, ServeReport, Trace};

use super::{LoweringCache, PolicyKind};

/// What the planner optimises against.
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    /// Offered load, requests per second.
    pub rate: f64,
    /// p99 time-to-first-token SLO, seconds.
    pub slo_p99_ttft: f64,
    /// Requests in the evaluation trace.
    pub n_requests: usize,
    /// Prompt / decode lengths of the synthetic trace.
    pub prompt: usize,
    pub decode: usize,
    /// Seed of the Poisson arrival stream (all candidates replay the
    /// identical trace).
    pub seed: u64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec { rate: 10.0, slo_p99_ttft: 0.5, n_requests: 64, prompt: 128, decode: 32, seed: 0 }
    }
}

/// One evaluated deployment.
#[derive(Debug, Clone)]
pub struct SloCandidate {
    pub stages: usize,
    pub tp: usize,
    pub max_batch: usize,
    pub report: ServeReport,
}

impl SloCandidate {
    pub fn meets(&self, slo: f64) -> bool {
        self.report.ttft_p99 <= slo
    }
}

/// Search outcome: the winner (feasible or closest miss), a diagnostic
/// when infeasible, and the full ranked table for reporting.
#[derive(Debug, Clone)]
pub struct SloPlan {
    /// Best candidate: highest tokens/sec among SLO-feasible ones, or
    /// the lowest-p99 one if nothing is feasible.
    pub best: SloCandidate,
    /// `None` when `best` meets the SLO; otherwise names the binding
    /// constraint.
    pub infeasible: Option<String>,
    /// Every evaluated candidate, ranked like the search (feasible by
    /// tokens/sec desc, then by p99 asc).
    pub evaluated: Vec<SloCandidate>,
    /// Deployments rejected before evaluation, as (stages, tp, reason).
    pub rejected: Vec<(usize, usize, String)>,
}

/// Stage counts to try: divisors of d_l up to the layer count.
fn stage_grid(d_l: usize) -> Vec<usize> {
    (1..=d_l.min(16)).filter(|s| d_l % s == 0).collect()
}

/// Tensor-parallel degrees to try: powers of two within one node.
fn tp_grid(cluster: &ClusterSpec) -> Vec<usize> {
    let mut g = vec![1usize];
    while g.last().unwrap() * 2 <= cluster.max_node_size {
        g.push(g.last().unwrap() * 2);
    }
    g
}

/// Batch caps to try, clamped to the KV admission limit per candidate.
const BATCH_GRID: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// Statically verify one serving deployment's prefill and decode
/// programs: whole-world compose at `{stages, dp = 1, tp}` with the
/// KV-aware memory model at the *worst* residency the batcher can
/// reach (cap requests at full context).
pub fn verify_serving(
    shape: &TransformerShape,
    cluster: &ClusterSpec,
    stages: usize,
    tp: usize,
    cap: usize,
    prompt: usize,
    decode: usize,
) -> Result<(), String> {
    let kv = KvCacheModel::new(shape, stages, tp, DType::F32, cluster.gpu.memory_bytes);
    let spec = ScheduleSpec {
        d_l: shape.d_l,
        n_l: stages,
        n_mu: cap,
        tp,
        partition: false,
        offload: false,
        data_parallel: false,
        zero: 0,
    };
    let topo = Topology::new(stages, 1, tp);
    for (kind, tokens_per_fwd, context) in [
        // Prefill: cold cache, each Fwd stashes a whole prompt.
        (PolicyKind::ServePrefill, prompt, 0usize),
        // Decode wave at the worst case: every slot one token from done.
        (PolicyKind::ServeDecode, 1, prompt + decode - 1),
    ] {
        let program = LoweringCache::global().lower(kind, &spec);
        let table = ServeCosts::new(shape, cluster, stages, tp).table(tokens_per_fwd);
        let model = MemoryModel::serving(&kv, &table, cap, context, tokens_per_fwd);
        verify_program(&program, topo, table.wire, Some(&model)).map_err(|errs| {
            format!("{} fails whole-world verify: {}", program.name, errs[0])
        })?;
    }
    Ok(())
}

/// Search the deployment grid. Every candidate replays the same seeded
/// trace; ranking is measured tokens/sec among SLO-feasible
/// candidates. Returns `Err` only if *no* deployment even admits one
/// request (the grid is structurally empty).
pub fn plan_slo(
    shape: &TransformerShape,
    cluster: &ClusterSpec,
    spec: &SloSpec,
) -> Result<SloPlan, String> {
    let trace = Trace::poisson(spec.seed, spec.rate, spec.n_requests, spec.prompt, spec.decode);
    let context = spec.prompt + spec.decode;
    let mut evaluated: Vec<SloCandidate> = Vec::new();
    let mut rejected: Vec<(usize, usize, String)> = Vec::new();

    for &stages in &stage_grid(shape.d_l) {
        for &tp in &tp_grid(cluster) {
            let kv = KvCacheModel::new(shape, stages, tp, DType::F32, cluster.gpu.memory_bytes);
            let admission = kv.admission_limit(context);
            if admission == 0 {
                rejected.push((
                    stages,
                    tp,
                    format!(
                        "kv-admission: weights {:.3e} B + one request {:.3e} B exceed \
                         budget {:.3e} B",
                        kv.weight_bytes,
                        kv.request_bytes(context),
                        kv.budget
                    ),
                ));
                continue;
            }
            // Distinct effective caps only (clamping collapses the top
            // of the batch grid onto the admission limit).
            let mut caps: Vec<usize> =
                BATCH_GRID.iter().map(|&b| b.min(admission)).collect();
            caps.dedup();
            for cap in caps {
                if let Err(e) =
                    verify_serving(shape, cluster, stages, tp, cap, spec.prompt, spec.decode)
                {
                    rejected.push((stages, tp, format!("cap {cap}: {e}")));
                    continue;
                }
                match run_trace(shape, cluster, stages, tp, cap, &trace) {
                    Ok(report) => {
                        evaluated.push(SloCandidate { stages, tp, max_batch: cap, report })
                    }
                    Err(e) => rejected.push((stages, tp, format!("cap {cap}: {e}"))),
                }
            }
        }
    }

    if evaluated.is_empty() {
        return Err(format!(
            "no deployment admits a single request at context {context}; tightest miss: {}",
            rejected
                .first()
                .map(|(s, t, r)| format!("stages={s} tp={t}: {r}"))
                .unwrap_or_else(|| "empty grid".into())
        ));
    }

    // Rank: feasible first by tokens/sec (desc), then closest miss by
    // p99 (asc).
    evaluated.sort_by(|a, b| {
        let fa = a.meets(spec.slo_p99_ttft);
        let fb = b.meets(spec.slo_p99_ttft);
        fb.cmp(&fa)
            .then_with(|| {
                if fa && fb {
                    b.report.tokens_per_sec.total_cmp(&a.report.tokens_per_sec)
                } else {
                    a.report.ttft_p99.total_cmp(&b.report.ttft_p99)
                }
            })
    });
    let best = evaluated[0].clone();
    let infeasible = if best.meets(spec.slo_p99_ttft) {
        None
    } else {
        Some(format!(
            "no deployment meets p99 TTFT ≤ {:.3}s at {} req/s: closest is stages={} \
             tp={} batch={} at p99 {:.3}s (binding constraint: {})",
            spec.slo_p99_ttft,
            spec.rate,
            best.stages,
            best.tp,
            best.max_batch,
            best.report.ttft_p99,
            if best.report.cap_bound == "kv-admission" {
                "KV admission limit caps the batch below the offered load"
            } else {
                "latency SLO (queueing at the offered rate)"
            }
        ))
    };
    Ok(SloPlan { best, infeasible, evaluated, rejected })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::XModel;

    #[test]
    fn grids_are_sane() {
        assert_eq!(stage_grid(8), vec![1, 2, 4, 8]);
        assert_eq!(stage_grid(12), vec![1, 2, 3, 4, 6, 12]);
        let g = tp_grid(&ClusterSpec::reference());
        assert_eq!(g[0], 1);
        assert!(g.windows(2).all(|w| w[1] == w[0] * 2));
    }

    #[test]
    fn verify_serving_passes_on_the_reference_cluster() {
        let shape = XModel::new(8).shape();
        let cluster = ClusterSpec::reference();
        verify_serving(&shape, &cluster, 2, 2, 4, 32, 8).expect("serving world must verify");
    }

    #[test]
    fn relaxed_slo_is_feasible_and_ranked_by_throughput() {
        let shape = XModel::new(8).shape();
        let cluster = ClusterSpec::reference();
        let spec = SloSpec {
            rate: 5.0,
            slo_p99_ttft: f64::INFINITY,
            n_requests: 8,
            prompt: 16,
            decode: 4,
            seed: 1,
        };
        let plan = plan_slo(&shape, &cluster, &spec).unwrap();
        assert!(plan.infeasible.is_none());
        assert!(!plan.evaluated.is_empty());
        // Winner has the highest tokens/sec of all evaluated (all are
        // feasible under an infinite SLO).
        let best_tps = plan.best.report.tokens_per_sec;
        assert!(plan
            .evaluated
            .iter()
            .all(|c| c.report.tokens_per_sec <= best_tps + 1e-9));
    }

    #[test]
    fn impossible_slo_reports_the_binding_constraint() {
        let shape = XModel::new(8).shape();
        let cluster = ClusterSpec::reference();
        let spec = SloSpec {
            rate: 5.0,
            slo_p99_ttft: 0.0, // unmeetable: TTFT is strictly positive
            n_requests: 4,
            prompt: 16,
            decode: 2,
            seed: 1,
        };
        let plan = plan_slo(&shape, &cluster, &spec).unwrap();
        let diag = plan.infeasible.expect("a zero SLO cannot be met");
        assert!(diag.contains("binding constraint"), "{diag}");
        // The closest miss is still a fully-evaluated deployment.
        assert!(plan.best.report.completed > 0);
    }
}
