//! Training-configuration planner (paper §5 "Optimal configuration").
//!
//! Implements the paper's selection rules for the fastest configuration
//! of each (strategy × parallelism-menu) pair, a constrained planner for
//! the time-budgeted Table 6.3, and a grid search used for the scaling
//! figures where the closed-form rules need to adapt (e.g. Ethernet).
//!
//! The grid search runs a four-stage pipeline, parallel and cached end
//! to end:
//!
//! ```text
//! enumerate ──► prune ──► evaluate ──► simulate
//! (candidates)  (memory bound,        (full cost   (lowering cache +
//!                branch-and-bound)     model)       event-loop engine)
//! ```
//!
//! * **enumerate** ([`candidates`]): the (n_a, n_l, n_μ, b_μ, offload,
//!   partition) grid as a lazy iterator in a fixed order, after the
//!   cheap structural filters (§5 rules, critical-batch budget).
//! * **prune** ([`search`]): a memory lower bound rejects unfittable
//!   candidates before any speed estimate, and a branch-and-bound cutoff
//!   drops candidates whose compute-only optimistic time already exceeds
//!   the incumbent.
//! * **evaluate** ([`search`]): the surviving candidates get the full
//!   cost model, fanned out over [`par::planner_threads`] scoped worker
//!   threads (self-scheduling work queue; set the `PLANNER_THREADS`
//!   environment variable to override the `available_parallelism`
//!   default — one thread per physical core is the sweet spot, and
//!   nested fan-outs collapse to serial automatically). The selection
//!   fold is order-identical to the retained serial reference,
//!   [`search::search_fastest_exhaustive`], so the optimised search
//!   provably returns the same plan (`tests/planner_parity.rs`).
//! * **simulate** ([`simloop`]): candidate plans are re-ranked by real
//!   simulated makespan, after the whole-world static verifier
//!   ([`search::statically_valid`] → [`crate::analysis`]) rejects any
//!   statically-invalid plan — structural verdicts are memoised in
//!   [`cache::LoweringCache`] alongside the lowerings, so the filter
//!   costs one hash lookup per candidate. Lowerings are memoised in
//!   [`cache::LoweringCache`] — the cache hits whenever two candidates
//!   snap to the same executable spec (n_a/n_b/b_μ differences only
//!   change the cost table, not the schedule), which in a typical sweep
//!   is almost every candidate after the first few — and the simulator
//!   runs timeline-off with per-worker scratch, so a simulation
//!   allocates nothing after warmup.

pub mod cache;
pub mod candidates;
pub mod constrained;
pub mod par;
pub mod reliability;
pub mod rules;
pub mod search;
pub mod simloop;
pub mod slo;

pub use cache::{LoweringCache, PolicyKind};
pub use candidates::Candidates;
pub use constrained::{min_gpu_plan, ConstrainedPlan};
pub use par::{par_map, par_map_with, planner_threads};
pub use reliability::{
    ckpt_interval_steps, lost_work_bound, plan_with_reliability, LostWorkBound, ReliabilityParams,
    ReliablePlan, CLASSIC_CKPT_INTERVAL_STEPS,
};
pub use rules::{fastest_plan, Plan, MAX_OVERHEAD};
pub use search::{
    search_fastest, search_fastest_exhaustive, search_fastest_tp, search_fastest_zero,
    statically_valid,
};
pub use simloop::{
    lower_plan, plan_spec, rank_by_simulation, simulate_plan, simulate_plan_with, SimulatedPlan,
};
pub use slo::{plan_slo, verify_serving, SloCandidate, SloPlan, SloSpec};
