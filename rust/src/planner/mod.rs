//! Training-configuration planner (paper §5 "Optimal configuration").
//!
//! Implements the paper's selection rules for the fastest configuration of
//! each (strategy × parallelism-menu) pair, a constrained planner for the
//! time-budgeted Table 6.3, and a grid search used for the scaling
//! figures where the closed-form rules need to adapt (e.g. Ethernet).

pub mod constrained;
pub mod rules;
pub mod search;
pub mod simloop;

pub use constrained::{min_gpu_plan, ConstrainedPlan};
pub use rules::{fastest_plan, Plan, MAX_OVERHEAD};
pub use search::search_fastest;
pub use simloop::{lower_plan, rank_by_simulation, simulate_plan, SimulatedPlan};
