//! Time-constrained planning (paper §6 "Smaller clusters", Table 6.3):
//! find the smallest cluster that trains the model within a wall-clock
//! budget, per strategy.
//!
//! Because the total training compute is fixed (b·steps is invariant below
//! the critical batch size), the GPU count needed for a time budget T is
//! `total_flops / (T · peak · efficiency)`; the planner enumerates
//! configuration structures, computes each one's efficiency, and keeps the
//! structure minimising the GPU count (tie-breaking on lower batch size,
//! which the paper counts as an implicit efficiency gain).

use crate::costmodel::{ParallelismMenu, Strategy, TrainConfig};
use crate::hardware::ClusterSpec;
use crate::model::{XModel, TRAINING_STEPS};

use super::rules::{max_tensor_parallel, Plan};

/// A plan selected under a time constraint.
#[derive(Debug, Clone)]
pub struct ConstrainedPlan {
    pub plan: Plan,
    /// The requested wall-clock budget, seconds.
    pub budget_secs: f64,
}

/// Smallest-cluster plan meeting `budget_secs` for a strategy+menu.
pub fn min_gpu_plan(
    model: &XModel,
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
    budget_secs: f64,
) -> Option<ConstrainedPlan> {
    let shape = model.shape();
    let bc = model.critical_batch_size();
    let total_flops = model.training_flops(bc, TRAINING_STEPS);
    let d_l = shape.d_l;

    let n_a_cands: Vec<usize> = {
        let cap = if menu.tensor { max_tensor_parallel(model, cluster) } else { 1 };
        let mut v: Vec<usize> = [1, 2, 4, 8, 16, 32].iter().copied().filter(|&a| a <= cap).collect();
        if !v.contains(&cap) {
            v.push(cap);
        }
        v
    };
    let n_l_cands: Vec<usize> = if menu.pipeline {
        [1usize, 2, 4, 5, 8, 10, 16, 20, 32, 40, 80, 160]
            .iter()
            .copied()
            .filter(|&l| l <= d_l)
            .collect()
    } else {
        vec![1]
    };
    let b_mu_cands = [1.0, 2.0, 4.0, 5.0, 8.0, 10.0, 16.0];
    let n_mu_factors = [1.0, 1.25, 2.0, 4.0];

    let mut best: Option<Plan> = None;
    for &n_a in &n_a_cands {
        for &n_l in &n_l_cands {
            if strategy == Strategy::Partitioned && n_l > 1 {
                continue;
            }
            for &f in &n_mu_factors {
                let n_mu = ((n_l as f64 * f).round() as usize).max(1);
                for &b_mu in &b_mu_cands {
                    for offload in [false, true] {
                        // Find the smallest n_b meeting the budget for
                        // this structure by fixed-point iteration on the
                        // efficiency (which itself depends on n_b through
                        // the batch size).
                        let partition = strategy != Strategy::Baseline;
                        let n_b_cap = if menu.data {
                            ((bc / (n_mu as f64 * b_mu)).floor() as usize).max(1)
                        } else {
                            1
                        };
                        let mut n_b: usize = 1;
                        let mut plan: Option<Plan> = None;
                        for _ in 0..12 {
                            let cfg = TrainConfig {
                                strategy, n_b, n_l, n_a, n_mu, b_mu, offload, partition,
                                zero: 0,
                            };
                            if cfg.validate().is_err() {
                                break;
                            }
                            let p = Plan::build_pub(model, cfg, cluster);
                            let need = total_flops
                                / (budget_secs * cluster.gpu.peak_flops * p.speed.efficiency);
                            let need_b = ((need / (n_l * n_a) as f64).ceil() as usize)
                                .max(1)
                                .min(n_b_cap);
                            if !menu.data && need_b > 1 {
                                plan = None;
                                break; // menu forbids data parallelism
                            }
                            if need_b == n_b {
                                plan = Some(p);
                                break;
                            }
                            n_b = need_b;
                            plan = Some(p);
                        }
                        let Some(p) = plan else { continue };
                        // Feasibility: batch within the critical budget,
                        // memory fits, actually meets the deadline.
                        if p.cfg.batch_size() > bc * 1.001 {
                            continue;
                        }
                        if !p.fits_gpu(cluster) {
                            continue;
                        }
                        if p.speed.training_secs > budget_secs * 1.02 {
                            continue;
                        }
                        let better = match &best {
                            None => true,
                            Some(b) => {
                                p.cfg.n_gpu() < b.cfg.n_gpu()
                                    || (p.cfg.n_gpu() == b.cfg.n_gpu()
                                        && p.cfg.batch_size() < b.cfg.batch_size())
                            }
                        };
                        if better {
                            best = Some(p);
                        }
                    }
                }
            }
        }
    }
    best.map(|plan| ConstrainedPlan { plan, budget_secs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::SECS_PER_DAY;

    /// Table 6.3 shape: one-month training of X_160 needs ~7-10k GPUs,
    /// six-month needs ~1.3k, with high efficiency for the improved
    /// method.
    #[test]
    fn table_6_3_cluster_sizes() {
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let month = 33.0 * SECS_PER_DAY;
        let half_year = 180.0 * SECS_PER_DAY;

        let p1 = min_gpu_plan(&model, &cluster, Strategy::Partitioned, ParallelismMenu::DATA_TENSOR, month)
            .expect("one-month partitioned plan");
        assert!(
            (p1.plan.cfg.n_gpu() as f64 / 7728.0 - 1.0).abs() < 0.10,
            "one-month data+tensor: {} GPUs (paper: 7728)",
            p1.plan.cfg.n_gpu()
        );

        let p2 = min_gpu_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D, half_year)
            .expect("six-month improved plan");
        assert!(
            (p2.plan.cfg.n_gpu() as f64 / 1320.0 - 1.0).abs() < 0.15,
            "six-month 3d improved: {} GPUs (paper: ~1320)",
            p2.plan.cfg.n_gpu()
        );
        assert!(p2.plan.speed.efficiency > 0.90);
    }

    #[test]
    fn improved_trains_without_tensor_parallelism_in_six_months() {
        // Table 6.3: "for the six-month training it is the only one able
        // to train without tensor parallelism".
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let half_year = 181.0 * SECS_PER_DAY;
        let p = min_gpu_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::DATA_PIPE, half_year);
        assert!(p.is_some());
        let p = p.unwrap();
        assert_eq!(p.plan.cfg.n_a, 1);
        assert!(p.plan.speed.training_secs <= half_year * 1.02);
    }

    #[test]
    fn tighter_budget_needs_more_gpus() {
        let model = XModel::new(64);
        let cluster = ClusterSpec::reference();
        let fast = min_gpu_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D, 5.0 * SECS_PER_DAY);
        let slow = min_gpu_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D, 50.0 * SECS_PER_DAY);
        let (f, s) = (fast.unwrap(), slow.unwrap());
        assert!(f.plan.cfg.n_gpu() > s.plan.cfg.n_gpu());
    }
}
