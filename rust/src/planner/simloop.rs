//! Simulate-in-the-loop plan refinement.
//!
//! The closed-form planner (rules / grid search) ranks configurations by
//! the cost model's efficiency estimate. This module re-ranks candidate
//! plans by actually *executing* their schedules on the discrete-event
//! simulator: each plan's schedule is lowered to a [`ScheduleProgram`]
//! and the O(V+E) engine measures the real makespan, including the
//! overlap effects the closed forms approximate (exposed sends, optimizer
//! serialisation, restore traffic).
//!
//! Cheap enough to run inside a planner search, for three reasons:
//! lowering is memoised through [`super::cache::LoweringCache`] (many
//! candidates snap to the same executable spec — n_b/b_μ only price
//! the cost table, they don't change the schedule, and n_a only flips
//! the tp > 1 op shape); candidates are simulated concurrently on
//! scoped worker threads; and each worker reuses one [`SimScratch`]
//! with the timeline off, so a simulation allocates nothing after
//! warmup.

use std::sync::Arc;

use crate::costmodel::{Strategy, TrainConfig};
use crate::hardware::ClusterSpec;
use crate::model::XModel;
use crate::schedule::{ScheduleProgram, ScheduleSpec};
use crate::sim::{simulate_program_into, CostTable, SimOptions, SimScratch};

use super::cache::{LoweringCache, PolicyKind};
use super::par::par_map_with;
use super::rules::Plan;

/// A plan annotated with its simulated execution.
#[derive(Debug, Clone)]
pub struct SimulatedPlan {
    pub plan: Plan,
    /// Simulated time for one batch on one data-parallel instance,
    /// seconds.
    pub makespan: f64,
    /// Simulated compute efficiency (comparable to
    /// `plan.speed.efficiency`).
    pub sim_efficiency: f64,
    /// Makespan normalised by the global batch (n_b data-parallel
    /// instances × n_mu micro-batches × b_mu sequences) — the
    /// cross-plan comparable figure even when plans split the batch
    /// differently across data parallelism.
    pub secs_per_sequence: f64,
}

/// Snap a planner configuration to an executable schedule shape: the
/// pipeline degree must divide the layer count and the micro-batch count
/// must feed every stage. Returns the adjusted config and spec. Public
/// so the static verifier ([`super::search::statically_valid`], the
/// `repro verify` CLI) analyses exactly the shape the planner would
/// execute.
pub fn plan_spec(d_l: usize, cfg: &TrainConfig) -> (TrainConfig, ScheduleSpec) {
    let mut cfg = *cfg;
    if cfg.strategy == Strategy::Partitioned {
        cfg.n_l = 1; // §5: the partitioned approach forgoes pipelining
    }
    while d_l % cfg.n_l != 0 {
        cfg.n_l -= 1;
    }
    cfg.n_mu = cfg.n_mu.max(cfg.n_l);
    let spec = ScheduleSpec {
        d_l,
        n_l: cfg.n_l,
        n_mu: cfg.n_mu,
        // Tensor-parallel plans now change the *schedule*, not just the
        // cost table: tp > 1 emits the per-layer TensorAllReduce ops the
        // simulator charges the amortised C.4.3 wire time for.
        tp: cfg.n_a,
        partition: cfg.partition,
        // Offloaded plans now simulate the ops they imply (restores on
        // the CPU link, post-step stores) instead of pricing offload in
        // the cost table only — sim/cost parity with the generators.
        offload: cfg.offload,
        data_parallel: cfg.n_b > 1,
        // ZeRO plans simulate the ops they imply: ≥2 swaps the reduce
        // for its reduce-scatter half, 1–2 gather post-step, 3 gathers
        // before every use.
        zero: cfg.zero,
    };
    (cfg, spec)
}

/// Lower the schedule a plan implies, returning the snapped executable
/// config alongside the (shared, memoised) program. The config prices
/// the cost table the program is simulated against — computing it once
/// keeps them from drifting apart. Baseline plans run standard GA / the
/// contiguous pipeline; improved and partitioned plans run layered
/// accumulation (modular pipeline when staged). Lowerings are served
/// from [`LoweringCache::global`], so re-planning the same snapped spec
/// costs one hash lookup.
pub fn lower_plan(model: &XModel, plan: &Plan) -> (TrainConfig, Arc<ScheduleProgram>) {
    let d_l = model.shape().d_l;
    let (cfg, spec) = plan_spec(d_l, &plan.cfg);
    let kind = PolicyKind::for_config(cfg.strategy, cfg.n_l);
    (cfg, LoweringCache::global().lower(kind, &spec))
}

/// Simulate one plan end-to-end and annotate it with measured numbers.
pub fn simulate_plan(model: &XModel, cluster: &ClusterSpec, plan: &Plan) -> SimulatedPlan {
    simulate_plan_with(model, cluster, plan, &mut SimScratch::new())
}

/// Scratch-reusing variant of [`simulate_plan`]: planner loops hold one
/// [`SimScratch`] per worker so back-to-back simulations allocate
/// nothing. The timeline is not recorded — the ranking only needs
/// makespan and busy time, which are bit-identical either way.
pub fn simulate_plan_with(
    model: &XModel,
    cluster: &ClusterSpec,
    plan: &Plan,
    scratch: &mut SimScratch,
) -> SimulatedPlan {
    let (cfg, program) = lower_plan(model, plan);
    let costs = CostTable::new(&model.shape(), &cfg, cluster);
    let r = simulate_program_into(
        &program,
        &costs,
        SimOptions { record_timeline: false },
        scratch,
    );
    let makespan = r.makespan;
    let sim_efficiency = r.compute_efficiency();
    scratch.recycle(r);
    // The makespan covers one data-parallel instance's n_mu·b_mu
    // sequences while n_b instances run concurrently: global
    // time-per-sequence divides by the full batch.
    let sequences = (cfg.n_b as f64 * cfg.n_mu as f64 * cfg.b_mu).max(1.0);
    SimulatedPlan {
        plan: plan.clone(),
        makespan,
        sim_efficiency,
        secs_per_sequence: makespan / sequences,
    }
}

/// Re-rank candidate plans by simulated seconds-per-sequence and return
/// the winner (first of equals, so the result is deterministic).
/// Candidates simulate concurrently; returns `None` on an empty set.
///
/// Each candidate first passes the whole-world static verifier
/// ([`super::search::statically_valid`]): a statically-invalid plan is
/// dropped before any simulation runs. For generated schedules the
/// filter accepts everything the planner's own feasibility checks
/// admit (the static memory bound is provably no larger than the
/// analytic one), so the selected plan is identical with or without
/// the filter — `tests/analysis.rs` proves it on the planner-parity
/// configurations.
pub fn rank_by_simulation(
    model: &XModel,
    cluster: &ClusterSpec,
    candidates: &[Plan],
) -> Option<SimulatedPlan> {
    let sims = par_map_with(candidates, SimScratch::new, |scratch, _, plan| {
        super::search::statically_valid(model, cluster, plan)
            .ok()
            .map(|()| simulate_plan_with(model, cluster, plan, scratch))
    });
    // `total_cmp`: a NaN cost (degenerate schedule) sorts deterministically
    // instead of panicking mid-sweep.
    sims.into_iter()
        .flatten()
        .min_by(|a, b| a.secs_per_sequence.total_cmp(&b.secs_per_sequence))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ParallelismMenu;
    use crate::planner::fastest_plan;

    #[test]
    fn simulated_efficiency_tracks_the_closed_form() {
        let model = XModel::new(64);
        let cluster = ClusterSpec::reference();
        let plan = fastest_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::DATA_PIPE)
            .expect("plan");
        let sp = simulate_plan(&model, &cluster, &plan);
        // The simulator adds costs the closed form ignores; allow a gap
        // but require the same ballpark.
        assert!(sp.makespan.is_finite() && sp.makespan > 0.0);
        assert!(
            sp.sim_efficiency > plan.speed.efficiency * 0.75,
            "sim eff {:.3} vs planned {:.3}",
            sp.sim_efficiency,
            plan.speed.efficiency
        );
    }

    #[test]
    fn ranking_prefers_the_improved_strategy() {
        let model = XModel::new(64);
        let cluster = ClusterSpec::reference();
        let base = fastest_plan(&model, &cluster, Strategy::Baseline, ParallelismMenu::DATA_PIPE)
            .expect("baseline plan");
        let impr = fastest_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::DATA_PIPE)
            .expect("improved plan");
        let best = rank_by_simulation(&model, &cluster, &[base, impr]).unwrap();
        assert_eq!(best.plan.cfg.strategy, Strategy::Improved);
    }

    #[test]
    fn lower_plan_serves_identical_programs_from_the_cache() {
        let model = XModel::new(64);
        let cluster = ClusterSpec::reference();
        let plan = fastest_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::DATA_PIPE)
            .expect("plan");
        let (cfg_a, prog_a) = lower_plan(&model, &plan);
        let (cfg_b, prog_b) = lower_plan(&model, &plan);
        assert_eq!(cfg_a, cfg_b);
        // Same snapped spec → the global cache returns the same Arc.
        assert!(Arc::ptr_eq(&prog_a, &prog_b));
    }

    #[test]
    fn parallel_ranking_is_deterministic() {
        let model = XModel::new(32);
        let cluster = ClusterSpec::reference();
        let plans: Vec<Plan> = Strategy::ALL
            .iter()
            .filter_map(|&s| fastest_plan(&model, &cluster, s, ParallelismMenu::THREE_D))
            .collect();
        assert!(plans.len() >= 2);
        let a = rank_by_simulation(&model, &cluster, &plans).unwrap();
        let b = rank_by_simulation(&model, &cluster, &plans).unwrap();
        assert_eq!(a.plan.cfg, b.plan.cfg);
        assert_eq!(a.secs_per_sequence.to_bits(), b.secs_per_sequence.to_bits());
    }
}
