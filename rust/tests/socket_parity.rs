//! Socket-vs-mpsc backend parity: swapping the TCP transport in for the
//! in-process channels must be invisible — loss trajectories bit for
//! bit, traffic accounting element for element — and a torn connection
//! must surface as a clean `Disconnected`, never a hang.
//!
//! The trainer-level tests are artifact-gated like the rest of the e2e
//! suite (skipped when the PJRT artifacts are absent); the transport-
//! level tests always run.

use std::path::PathBuf;
use std::thread;

use lga_mpp::collective::{
    ring_group, socket_pair, socket_ring, Disconnected, RingGroup, Transport,
};
use lga_mpp::optim::LrSchedule;
use lga_mpp::runtime::DType;
use lga_mpp::trainer::{launch, train, TrainerConfig};

fn have_artifacts() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny/manifest.json").exists()
}

fn base(steps: usize) -> TrainerConfig {
    let mut c = TrainerConfig::quick("tiny");
    c.steps = steps;
    c.n_mu = 2;
    c.lr = LrSchedule::constant(3e-3);
    c
}

fn assert_bitwise(mpsc: &[f64], socket: &[f64]) {
    assert_eq!(mpsc.len(), socket.len(), "step counts differ");
    for (i, (a, b)) in mpsc.iter().zip(socket).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "step {i}: mpsc {a} vs socket {b}");
    }
}

/// The ISSUE acceptance spec: tp=2 / dp=2 over loopback sockets, loss
/// trajectory bit-identical to the single-process mpsc run, traffic
/// totals equal (the wire barrier's tokens bypass the accounting), and
/// the bytes-on-wire columns exactly elems x f32 width.
#[test]
fn socket_tp2_dp2_matches_mpsc_bit_for_bit() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base(3);
    cfg.n_b = 2;
    cfg.tp = 2;
    let mpsc = train(&cfg).unwrap();
    let launched = launch::launch_threads(&cfg).unwrap();
    let r = &launched.report;
    assert_bitwise(&mpsc.losses, &r.losses);
    assert_eq!(r.schedule_name, mpsc.schedule_name);
    assert_eq!(r.collective_elems_sent, mpsc.collective_elems_sent);
    assert_eq!(r.pipeline_elems_sent, mpsc.pipeline_elems_sent);
    assert_eq!(r.tp_elems_sent, mpsc.tp_elems_sent);
    let w = DType::F32.bytes() as u64;
    assert_eq!(r.collective_bytes_sent, r.collective_elems_sent * w);
    assert_eq!(r.pipeline_bytes_sent, r.pipeline_elems_sent * w);
    assert_eq!(r.tp_bytes_sent, r.tp_elems_sent * w);
    assert_eq!(launched.per_rank.len(), 4);
}

/// All three axes at once (8 ranks: pp=2, dp=2, tp=2): every group kind
/// of the world runs over TCP and the trajectory still bit-matches.
#[test]
fn socket_full_3d_world_matches_mpsc_bit_for_bit() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base(2);
    cfg.n_l = 2;
    cfg.n_b = 2;
    cfg.tp = 2;
    cfg.force_tp_emulation = true;
    let mpsc = train(&cfg).unwrap();
    let launched = launch::launch_threads(&cfg).unwrap();
    assert_bitwise(&mpsc.losses, &launched.report.losses);
    assert_eq!(launched.report.collective_elems_sent, mpsc.collective_elems_sent);
    assert_eq!(launched.report.pipeline_elems_sent, mpsc.pipeline_elems_sent);
    assert_eq!(launched.report.tp_elems_sent, mpsc.tp_elems_sent);
}

/// Tearing the remote end mid-conversation yields `Disconnected` from
/// both directions within bounded work — no hang, no panic.
#[test]
fn torn_connection_surfaces_disconnected_not_a_hang() {
    let (mut a, b) = socket_pair::<Vec<f32>>().unwrap();
    a.send(vec![1.0, 2.0]).unwrap();
    drop(b);
    assert_eq!(a.recv(), Err(Disconnected));
    let mut saw_err = false;
    for _ in 0..10_000 {
        if a.send(vec![0.0; 16 * 1024]).is_err() {
            saw_err = true;
            break;
        }
    }
    assert!(saw_err, "writes into a torn connection never failed");
}

fn payload(r: usize) -> Vec<f32> {
    // 33 elements: not divisible by the ring size, so chunk boundaries
    // are uneven — the case where backend-dependent chunking would show.
    (0..33).map(|k| ((r * 1000 + k) as f32).sin()).collect()
}

fn run_ring(groups: Vec<RingGroup>) -> Vec<Vec<f32>> {
    let handles: Vec<_> = groups
        .into_iter()
        .enumerate()
        .map(|(r, mut g)| {
            thread::spawn(move || {
                let mut d = payload(r);
                g.all_reduce(&mut d);
                d
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// A 4-rank all-reduce of an awkward (non-divisible) length is
/// bit-identical between the mpsc rings and the socket rings.
#[test]
fn socket_ring_all_reduce_matches_mpsc_for_awkward_lengths() {
    let n = 4;
    let wire: Vec<RingGroup> = socket_ring(n)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(r, p)| RingGroup::new_wire(r, n, Box::new(p)))
        .collect();
    let a = run_ring(ring_group(n));
    let b = run_ring(wire);
    for (r, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.len(), y.len(), "rank {r}");
        for (k, (u, v)) in x.iter().zip(y).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "rank {r} elem {k}: {u} vs {v}");
        }
    }
    // And the reduction is rank-invariant on both backends.
    for x in &a[1..] {
        assert_eq!(x, &a[0]);
    }
}
