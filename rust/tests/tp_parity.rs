//! Tensor-parallel execution parity and traffic accounting, end to end
//! on the real trainer (artifacts-gated; skipped when the PJRT
//! artifacts are absent).
//!
//! 1. **Loss parity**: a tp = 2 run executes every `TensorAllReduce`
//!    over the CommWorld tp ring as a sum-then-1/tp-postscale roundtrip
//!    that is exact on the replicated values (prescaling instead would
//!    round subnormals — see `trainer::worker::tp_all_reduce`), so its
//!    loss trajectory must equal the tp = 1 run's **bit for bit** —
//!    including combined with pipeline and data parallelism.
//! 2. **Traffic accounting**: the per-group element counts the workers
//!    report must equal the volume the *schedule* implies — pipeline
//!    sends × activation size, tp all-reduces × ring traffic, dp
//!    reduces × parameter size — closing the loop between the compiled
//!    program and the wire.

use std::path::PathBuf;

use lga_mpp::optim::LrSchedule;
use lga_mpp::runtime::Manifest;
use lga_mpp::schedule::{lower, Op};
use lga_mpp::trainer::{train, Policy, TrainerConfig};

fn have_artifacts() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny/manifest.json").exists()
}

fn base(steps: usize) -> TrainerConfig {
    let mut c = TrainerConfig::quick("tiny");
    c.steps = steps;
    c.n_mu = 2;
    c.lr = LrSchedule::constant(3e-3);
    c
}

fn assert_bitwise_loss_match(a: &TrainerConfig, b: &TrainerConfig) {
    let ra = train(a).unwrap();
    let rb = train(b).unwrap();
    assert_eq!(ra.losses.len(), rb.losses.len());
    for (i, (x, y)) in ra.losses.iter().zip(&rb.losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "step {i}: {x} vs {y}");
    }
}

#[test]
fn tp2_matches_tp1_bitwise_single_stage() {
    if !have_artifacts() {
        return;
    }
    let a = base(6);
    let mut b = a.clone();
    b.tp = 2;
    assert_bitwise_loss_match(&a, &b);
}

#[test]
fn tp2_matches_tp1_bitwise_with_pipeline_and_dp() {
    if !have_artifacts() {
        return;
    }
    // tiny has 2 layers: 2 stages (modular), 2 dp instances, tp 2 —
    // 8 ranks exercising every group of the CommWorld at once.
    let mut a = base(4);
    a.n_l = 2;
    a.n_b = 2;
    let mut b = a.clone();
    b.tp = 2;
    assert_bitwise_loss_match(&a, &b);
}

#[test]
fn tp2_matches_tp1_bitwise_with_partition() {
    if !have_artifacts() {
        return;
    }
    let mut a = base(4);
    a.n_b = 2;
    a.partition = true;
    let mut b = a.clone();
    b.tp = 2;
    assert_bitwise_loss_match(&a, &b);
}

#[test]
fn per_group_traffic_matches_the_schedule_volume() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base(3);
    cfg.n_l = 2;
    cfg.n_b = 2;
    cfg.tp = 2;
    cfg.policy = Policy::Improved;

    let manifest =
        Manifest::load(&cfg.artifacts_root, &cfg.preset).expect("tiny manifest loads");
    let m = manifest.model;
    let act_elems = (manifest.batch * m.d_seq * m.d_model) as u64;
    let layer_elems = manifest.layer_param_elements() as u64;

    let program = lower(&cfg.build_schedule(m.n_layers)).expect("schedule lowers");
    let sends = program.count(|o| matches!(o, Op::SendAct { .. } | Op::SendGrad { .. })) as u64;
    let tars = program.count(|o| matches!(o, Op::TensorAllReduce { .. })) as u64;
    let reduces = program.count(|o| matches!(o, Op::ReduceGrad { .. })) as u64;

    let steps = cfg.steps as u64;
    let (dp, tp) = (cfg.n_b as u64, cfg.tp as u64);

    let r = train(&cfg).unwrap();

    // Pipeline: every send op moves one activation-sized payload, on
    // every (dp, tp) replica of the pipeline, every step.
    assert_eq!(r.pipeline_elems_sent, steps * dp * tp * sends * act_elems);

    // Tensor-parallel: each TensorAllReduce ring-sums one activation
    // over the 2-rank tp group — for n = 2 every rank sends exactly
    // `len` elements (both chunks cross the wire once per phase).
    assert_eq!(r.tp_elems_sent, steps * dp * tp * tars * act_elems);

    // Data-parallel: each ReduceGrad all-reduces one layer's parameters
    // over the 2-rank dp group (again `len` per rank for n = 2), plus
    // the per-step epilogue reduces of the embedding / positional /
    // head gradients on their owning stages.
    let epilogue =
        (m.vocab * m.d_model + m.d_seq * m.d_model + m.d_model * m.vocab) as u64;
    assert_eq!(
        r.collective_elems_sent,
        steps * dp * tp * (reduces * layer_elems + epilogue)
    );
}

#[test]
fn tp1_moves_no_tp_traffic() {
    if !have_artifacts() {
        return;
    }
    let r = train(&base(2)).unwrap();
    assert_eq!(r.tp_elems_sent, 0);
    assert_eq!(r.pipeline_elems_sent, 0, "single stage: no pipeline traffic");
    assert_eq!(r.collective_elems_sent, 0, "single instance: no dp traffic");
}
