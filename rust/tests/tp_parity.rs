//! Tensor-parallel execution parity, memory and traffic accounting, end
//! to end on the real trainer (artifacts-gated; skipped when the PJRT
//! artifacts are absent).
//!
//! Two execution modes, two contracts:
//!
//! 1. **Replicated-compute emulation** (`force_tp_emulation`): every
//!    `TensorAllReduce` is a sum-then-1/tp-postscale roundtrip that is
//!    exact on replicated values, so a tp = 2 run's loss trajectory must
//!    equal the tp = 1 run's **bit for bit** — including combined with
//!    pipeline and data parallelism.
//! 2. **Sharded execution** (Megatron-style column/row-parallel
//!    half-layer artifacts): per-rank parameters/optimizer state shrink
//!    to the owned shard (measured, ≈ 1/tp for the layer state) and the
//!    loss matches tp = 1 within a documented tolerance — the
//!    row-parallel partial sums reassociate one reduction axis, and the
//!    sharded forward runs the reference math where tp = 1 runs the
//!    Pallas kernels. The per-group element counts must equal the
//!    volume the schedule + sharded data flow imply: per layer pass,
//!    2 activation all-reduces forward (mid-layer + boundary) and 3
//!    backward (recompute + FFN-gradient + boundary), plus one bunched
//!    layernorm-gradient reduce per layer per step.

use std::path::PathBuf;

use lga_mpp::optim::LrSchedule;
use lga_mpp::runtime::Manifest;
use lga_mpp::schedule::{lower, Op};
use lga_mpp::trainer::{train, Policy, TrainerConfig};

fn have_artifacts() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny/manifest.json").exists()
}

fn tiny_manifest() -> Manifest {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(root, "tiny").expect("tiny manifest loads")
}

fn have_sharded_artifacts() -> bool {
    have_artifacts() && tiny_manifest().supports_tp(2)
}

fn base(steps: usize) -> TrainerConfig {
    let mut c = TrainerConfig::quick("tiny");
    c.steps = steps;
    c.n_mu = 2;
    c.lr = LrSchedule::constant(3e-3);
    c
}

fn assert_bitwise_loss_match(a: &TrainerConfig, b: &TrainerConfig) {
    let ra = train(a).unwrap();
    let rb = train(b).unwrap();
    assert_eq!(ra.losses.len(), rb.losses.len());
    for (i, (x, y)) in ra.losses.iter().zip(&rb.losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "step {i}: {x} vs {y}");
    }
}

/// The documented sharded-vs-unsharded loss tolerance: the row-parallel
/// reductions reassociate one summation axis and the sharded forward
/// uses the reference math (vs the Pallas kernels at tp = 1), so the
/// match is tight but not bitwise.
const SHARDED_LOSS_TOL: f64 = 5e-3;

fn assert_tolerance_loss_match(a: &TrainerConfig, b: &TrainerConfig) {
    let ra = train(a).unwrap();
    let rb = train(b).unwrap();
    assert_eq!(ra.losses.len(), rb.losses.len());
    for (i, (x, y)) in ra.losses.iter().zip(&rb.losses).enumerate() {
        assert!(
            (x - y).abs() < SHARDED_LOSS_TOL,
            "step {i}: {x} vs {y} (tol {SHARDED_LOSS_TOL})"
        );
    }
}

// ---------------------------------------------------------------------------
// Emulation mode: bitwise.
// ---------------------------------------------------------------------------

#[test]
fn tp2_emulation_matches_tp1_bitwise_single_stage() {
    if !have_artifacts() {
        return;
    }
    let a = base(6);
    let mut b = a.clone();
    b.tp = 2;
    b.force_tp_emulation = true;
    assert_bitwise_loss_match(&a, &b);
}

#[test]
fn tp2_emulation_matches_tp1_bitwise_with_pipeline_and_dp() {
    if !have_artifacts() {
        return;
    }
    // tiny has 2 layers: 2 stages (modular), 2 dp instances, tp 2 —
    // 8 ranks exercising every group of the CommWorld at once.
    let mut a = base(4);
    a.n_l = 2;
    a.n_b = 2;
    let mut b = a.clone();
    b.tp = 2;
    b.force_tp_emulation = true;
    assert_bitwise_loss_match(&a, &b);
}

#[test]
fn tp2_emulation_matches_tp1_bitwise_with_partition() {
    if !have_artifacts() {
        return;
    }
    let mut a = base(4);
    a.n_b = 2;
    a.partition = true;
    let mut b = a.clone();
    b.tp = 2;
    b.force_tp_emulation = true;
    assert_bitwise_loss_match(&a, &b);
}

// ---------------------------------------------------------------------------
// Sharded mode: tolerance loss match, 1/tp memory, exact traffic.
// ---------------------------------------------------------------------------

#[test]
fn tp2_sharded_loss_matches_tp1_within_tolerance_single_stage() {
    if !have_sharded_artifacts() {
        return;
    }
    let a = base(6);
    let mut b = a.clone();
    b.tp = 2;
    assert_tolerance_loss_match(&a, &b);
}

#[test]
fn tp2_sharded_loss_matches_across_pipeline_dp_partition_combos() {
    if !have_sharded_artifacts() {
        return;
    }
    // (n_l, n_b, partition): pipeline, data parallel, and the ZeRO-style
    // partition each interact with the sharded state differently.
    for (n_l, n_b, partition) in [(2usize, 1usize, false), (1, 2, false), (1, 2, true)] {
        let mut a = base(4);
        a.n_l = n_l;
        a.n_b = n_b;
        a.partition = partition;
        let mut b = a.clone();
        b.tp = 2;
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        assert!(rb.tp_sharded, "sharded mode expected");
        for (i, (x, y)) in ra.losses.iter().zip(&rb.losses).enumerate() {
            assert!(
                (x - y).abs() < SHARDED_LOSS_TOL,
                "n_l={n_l} n_b={n_b} partition={partition} step {i}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn tp2_sharded_layer_state_is_half_of_tp1_measured() {
    if !have_sharded_artifacts() {
        return;
    }
    let a = base(2);
    let mut b = a.clone();
    b.tp = 2;
    let ra = train(&a).unwrap();
    let rb = train(&b).unwrap();
    assert!(!ra.tp_sharded && rb.tp_sharded);
    // Layer params + Adam moments: per-rank resident bytes ≈ 1/2 (the
    // replicated layernorms and post-reduce biases add a sliver).
    let ratio = rb.max_layer_state_bytes as f64 / ra.max_layer_state_bytes as f64;
    assert!(
        ratio > 0.5 && ratio < 0.56,
        "sharded layer state {} vs full {} (ratio {ratio:.4})",
        rb.max_layer_state_bytes,
        ra.max_layer_state_bytes
    );
    // Total state includes the replicated embedding/head, so it shrinks
    // strictly but by less than 2x.
    assert!(rb.max_state_bytes < ra.max_state_bytes);
    // Emulation replicates everything: same footprint as tp = 1.
    let mut c = b.clone();
    c.force_tp_emulation = true;
    let rc = train(&c).unwrap();
    assert_eq!(rc.max_layer_state_bytes, ra.max_layer_state_bytes);
}

#[test]
fn sharded_traffic_matches_the_dataflow_volume() {
    if !have_sharded_artifacts() {
        return;
    }
    let mut cfg = base(3);
    cfg.n_l = 2;
    cfg.n_b = 2;
    cfg.tp = 2;
    cfg.policy = Policy::Improved;

    let manifest = tiny_manifest();
    let m = manifest.model;
    let act_elems = (manifest.batch * m.d_seq * m.d_model) as u64;

    let program = lower(&cfg.build_schedule(m.n_layers)).expect("schedule lowers");
    let fwd_tars = program
        .count(|o| matches!(o, Op::TensorAllReduce { bwd: false, .. })) as u64;
    let bwd_tars = program
        .count(|o| matches!(o, Op::TensorAllReduce { bwd: true, .. })) as u64;

    let steps = cfg.steps as u64;
    let (dp, tp) = (cfg.n_b as u64, cfg.tp as u64);

    let r = train(&cfg).unwrap();
    assert!(r.tp_sharded);

    // Per rank, a 2-rank ring all-reduce of `len` elements sends `len`.
    // Forward pass of a layer: the in-op mid-layer reduce + the
    // scheduled boundary reduce = 2 activation reduces; backward: the
    // x2 recompute + the FFN input-gradient reduce + the boundary
    // reduce = 3. Plus one bunched layernorm-gradient reduce (4·d_m
    // elements) per layer per step on every rank.
    let ln_elems = 4 * m.d_model as u64;
    let want = steps
        * dp
        * tp
        * ((2 * fwd_tars + 3 * bwd_tars) * act_elems + m.n_layers as u64 * ln_elems);
    assert_eq!(r.tp_elems_sent, want);
}

#[test]
fn emulated_traffic_matches_the_schedule_volume() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base(3);
    cfg.n_l = 2;
    cfg.n_b = 2;
    cfg.tp = 2;
    cfg.force_tp_emulation = true;
    cfg.policy = Policy::Improved;

    let manifest = tiny_manifest();
    let m = manifest.model;
    let act_elems = (manifest.batch * m.d_seq * m.d_model) as u64;
    let layer_elems = manifest.layer_param_elements() as u64;

    let program = lower(&cfg.build_schedule(m.n_layers)).expect("schedule lowers");
    let sends = program.count(|o| matches!(o, Op::SendAct { .. } | Op::SendGrad { .. })) as u64;
    let tars = program.count(|o| matches!(o, Op::TensorAllReduce { .. })) as u64;
    let reduces = program.count(|o| matches!(o, Op::ReduceGrad { .. })) as u64;

    let steps = cfg.steps as u64;
    let (dp, tp) = (cfg.n_b as u64, cfg.tp as u64);

    let r = train(&cfg).unwrap();
    assert!(!r.tp_sharded);

    // Pipeline: every send op moves one activation-sized payload, on
    // every (dp, tp) replica of the pipeline, every step.
    assert_eq!(r.pipeline_elems_sent, steps * dp * tp * sends * act_elems);

    // Tensor-parallel: each TensorAllReduce ring-sums one activation
    // over the 2-rank tp group — for n = 2 every rank sends exactly
    // `len` elements (both chunks cross the wire once per phase).
    assert_eq!(r.tp_elems_sent, steps * dp * tp * tars * act_elems);

    // Data-parallel: each ReduceGrad all-reduces one layer's parameters
    // over the 2-rank dp group (again `len` per rank for n = 2), plus
    // the per-step epilogue reduces of the embedding / positional /
    // head gradients on their owning stages.
    let epilogue =
        (m.vocab * m.d_model + m.d_seq * m.d_model + m.d_model * m.vocab) as u64;
    assert_eq!(
        r.collective_elems_sent,
        steps * dp * tp * (reduces * layer_elems + epilogue)
    );
}

#[test]
fn tp_resharding_resume_continues_the_trajectory() {
    if !have_sharded_artifacts() {
        return;
    }
    // A tp = 2 sharded run streams per-(layer, tp-rank) checkpoint
    // slots; resuming at tp = 1 must reassemble the full state from the
    // writer's shards (scatter through the writer's layout) and carry
    // the trajectory on. Compared against an uninterrupted tp = 2 run:
    // steps before the switch match exactly, steps after within the
    // sharded-vs-unsharded tolerance.
    let dir_same = std::env::temp_dir()
        .join(format!("lga_tp_resume_same_{}", std::process::id()));
    let dir_reshard = std::env::temp_dir()
        .join(format!("lga_tp_resume_reshard_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir_same);
    let _ = std::fs::remove_dir_all(&dir_reshard);

    let mut uninterrupted = base(6);
    uninterrupted.tp = 2;
    let reference = train(&uninterrupted).unwrap();
    assert!(reference.tp_sharded);

    // Two identical 3-step sharded prefixes, one store each (training is
    // deterministic, so both leave the same step-2 checkpoint).
    for dir in [&dir_same, &dir_reshard] {
        let mut first = base(3);
        first.tp = 2;
        first.offload = true;
        first.store_dir = Some(dir.clone());
        let r1 = train(&first).unwrap();
        assert!(r1.tp_sharded);
        // The store ops only *read* state, so the prefix matches the
        // uninterrupted run exactly (same math, offload on vs off).
        for (x, y) in r1.losses.iter().zip(&reference.losses) {
            assert!((x - y).abs() < 1e-12, "same config, same prefix: {x} vs {y}");
        }
    }

    // Matching layouts (tp 2 → tp 2): the fast path reads each rank's
    // own shard slot; the f32 store roundtrip is exact, so the resumed
    // steps reproduce the uninterrupted run's.
    let mut same = base(6);
    same.tp = 2;
    same.offload = true;
    same.store_dir = Some(dir_same.clone());
    same.resume = true;
    let rs = train(&same).unwrap();
    assert_eq!(rs.start_step, 3, "resume from the last complete step");
    for (i, (x, y)) in rs.losses.iter().zip(&reference.losses[3..]).enumerate() {
        assert!((x - y).abs() < 1e-12, "same-tp resumed step {}: {x} vs {y}", 3 + i);
    }

    // tp change (2 → 1): the writer's shard slots must merge back into
    // the full state; continuation within the sharded-vs-unsharded
    // tolerance.
    let mut second = base(6);
    second.tp = 1;
    second.offload = true;
    second.store_dir = Some(dir_reshard.clone());
    second.resume = true;
    let r2 = train(&second).unwrap();
    assert_eq!(r2.start_step, 3, "resume from the last complete step");
    for (i, (x, y)) in r2.losses.iter().zip(&reference.losses[3..]).enumerate() {
        assert!(
            (x - y).abs() < SHARDED_LOSS_TOL,
            "resumed step {}: {x} vs {y}",
            3 + i
        );
    }
    let _ = std::fs::remove_dir_all(&dir_same);
    let _ = std::fs::remove_dir_all(&dir_reshard);
}

#[test]
fn tp1_moves_no_tp_traffic() {
    if !have_artifacts() {
        return;
    }
    let r = train(&base(2)).unwrap();
    assert_eq!(r.tp_elems_sent, 0);
    assert_eq!(r.pipeline_elems_sent, 0, "single stage: no pipeline traffic");
    assert_eq!(r.collective_elems_sent, 0, "single instance: no dp traffic");
}
