//! Property tests for the generalized collective layer: ring collectives
//! over the `Transport` trait and the `CommWorld` process-group wiring.
//!
//! The properties the trainer's correctness rests on:
//! * ring all-reduce / all-gather results are **bit-identical across
//!   ranks** (deterministic chunking) for every group size and for
//!   uneven chunk splits;
//! * sums are exact against a serial reference on integer-valued data;
//! * per-rank traffic matches the 2·(n−1)/n bandwidth-optimal bound the
//!   paper's C.4.1 accounting assumes (for divisible lengths);
//! * a `CommWorld` routes each group along exactly one topology axis.

use std::thread;

use lga_mpp::collective::{ring_group, CommWorld, RingGroup, Topology};

/// Deterministic per-rank integer-valued test data: exact under f32
/// summation for the sizes used here, so cross-rank equality can be
/// asserted bitwise against a serial reference.
fn rank_data(rank: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| ((rank * 31 + i * 7) % 113) as f32 - 17.0).collect()
}

fn run_ring<F>(n: usize, len: usize, f: F) -> Vec<(Vec<f32>, u64)>
where
    F: Fn(&mut RingGroup, &mut Vec<f32>) + Send + Sync + Copy + 'static,
{
    let handles: Vec<_> = ring_group(n)
        .into_iter()
        .map(|mut g| {
            thread::spawn(move || {
                let mut d = rank_data(g.rank, len);
                f(&mut g, &mut d);
                (d, g.sent_elems())
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn all_reduce_is_bit_identical_across_ranks_for_n_1_through_8() {
    for n in 1..=8usize {
        // Lengths exercising even splits, uneven splits and len < n.
        for len in [1usize, 3, 16, 97, 256] {
            let results = run_ring(n, len, |g, d| g.all_reduce(d));
            let want: Vec<f32> = (0..len)
                .map(|i| (0..n).map(|r| rank_data(r, len)[i]).sum())
                .collect();
            for (rank, (res, _)) in results.iter().enumerate() {
                assert_eq!(res.len(), len);
                for (a, b) in res.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} len={len} rank={rank}");
                }
            }
        }
    }
}

#[test]
fn all_gather_reconstructs_identically_from_owned_chunks() {
    for n in 1..=8usize {
        for len in [1usize, 7, 64, 101] {
            let results = run_ring(n, len, |g, d| {
                // Start from a rank-coloured buffer, zero everything but
                // the owned chunk, then all-gather: every rank must end
                // with the identical assembly of the owned chunks.
                let (a, b) = g.owned_range(d.len());
                let own: Vec<f32> = d[a..b].to_vec();
                d.fill(0.0);
                d[a..b].copy_from_slice(&own);
                g.all_gather_owned(d);
            });
            // Reference: rank r's owned chunk of its own colour.
            let mut want = vec![0.0f32; len];
            {
                let groups = ring_group(n);
                for g in &groups {
                    let (a, b) = g.owned_range(len);
                    want[a..b].copy_from_slice(&rank_data(g.rank, len)[a..b]);
                }
            }
            for (rank, (res, _)) in results.iter().enumerate() {
                for (a, b) in res.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} len={len} rank={rank}");
                }
            }
        }
    }
}

#[test]
fn traffic_matches_the_ring_bound_for_divisible_lengths() {
    for n in 2..=8usize {
        let len = n * 40;
        for (_, sent) in run_ring(n, len, |g, d| g.all_reduce(d)) {
            // 2·(n−1)/n·len elements per rank.
            assert_eq!(sent, (2 * (n - 1) * (len / n)) as u64, "n={n}");
        }
    }
}

#[test]
fn uneven_chunks_cover_every_element_exactly_once() {
    // reduce-scatter ownership over an uneven split: the owned ranges
    // partition the buffer, so the scattered chunks reassemble exactly.
    for n in [3usize, 5, 7] {
        let len = 2 * n + 3; // never divisible by n
        let results = run_ring(n, len, |g, d| {
            g.reduce_scatter(d);
            let (a, b) = g.owned_range(d.len());
            let own: Vec<f32> = d[a..b].to_vec();
            d.fill(0.0);
            d[a..b].copy_from_slice(&own);
            g.all_gather_owned(d);
        });
        let want: Vec<f32> =
            (0..len).map(|i| (0..n).map(|r| rank_data(r, len)[i]).sum()).collect();
        for (res, _) in &results {
            for (a, b) in res.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
            }
        }
    }
}

#[test]
fn comm_world_groups_are_axis_disjoint() {
    // A 2×2×2 world: each rank all-reduces a marker over its dp group,
    // then over its tp group. The sums must mix exactly the intended
    // axis — any cross-talk between the 12 rings would break the value.
    let topo = Topology::new(2, 2, 2);
    let (worlds, _loss_rx) = CommWorld::build(topo);
    let handles: Vec<_> = worlds
        .into_iter()
        .map(|mut w| {
            thread::spawn(move || {
                let r = w.rank();
                let marker = (100 * r.stage + 10 * r.dp + r.tp) as f32;
                let mut dp_buf = vec![marker, 1.0];
                w.dp_group().all_reduce(&mut dp_buf);
                let mut tp_buf = vec![marker, 1.0];
                w.tp_group().all_reduce(&mut tp_buf);
                w.step_barrier();
                (r, dp_buf[0], tp_buf[0], w.traffic())
            })
        })
        .collect();
    for h in handles {
        let (r, dp_sum, tp_sum, traffic) = h.join().unwrap();
        // dp axis: sum over dp ∈ {0,1} at fixed (stage, tp).
        assert_eq!(dp_sum, (2 * (100 * r.stage) + 10 + 2 * r.tp) as f32, "{r:?}");
        // tp axis: sum over tp ∈ {0,1} at fixed (stage, dp).
        assert_eq!(tp_sum, (2 * (100 * r.stage + 10 * r.dp)) as f32 + 1.0, "{r:?}");
        // 2-elem all-reduce over a 2-ring: 2 elements per rank per group.
        assert_eq!(traffic.dp, 2);
        assert_eq!(traffic.tp, 2);
        assert_eq!(traffic.pipeline, 0);
    }
}
