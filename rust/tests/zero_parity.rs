//! ZeRO state-sharding execution parity, memory and resume accounting,
//! end to end on the real trainer (artifacts-gated; skipped when the
//! PJRT artifacts are absent).
//!
//! The contract mirrors `tp_parity.rs` for the new axis:
//!
//! 1. **Bitwise loss parity** — a zero ∈ {1,2,3} run's loss trajectory
//!    must equal the zero = 0 run's **bit for bit**, including combined
//!    with pipeline, data and (emulated) tensor parallelism. The ring
//!    reduce-scatter keeps exactly the chunks the all-reduce would have
//!    produced, each rank updates only its owned slice, and the
//!    all-gather redistributes the identical updated values.
//! 2. **Measured state slope** — per-rank Adam moments shrink to the
//!    owned 1/dp range (stage ≥ 1), so the measured
//!    `max_layer_state_bytes` drops from 12 to (4 + 8/dp) bytes per
//!    parameter while params stay replicated.
//! 3. **Elastic resume across a zero change** — checkpoints written
//!    under zero = 2 carry `[lo, hi)` shard provenance; a zero = 0
//!    resume reassembles the full state and continues the trajectory,
//!    and the reverse direction re-slices full records to the owned
//!    range.

use std::path::PathBuf;

use lga_mpp::optim::LrSchedule;
use lga_mpp::schedule::{lower, Op};
use lga_mpp::trainer::{train, TrainerConfig};

fn have_artifacts() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny/manifest.json").exists()
}

fn base(steps: usize) -> TrainerConfig {
    let mut c = TrainerConfig::quick("tiny");
    c.steps = steps;
    c.n_mu = 2;
    c.lr = LrSchedule::constant(3e-3);
    c
}

fn assert_bitwise_loss_match(a: &TrainerConfig, b: &TrainerConfig, label: &str) {
    let ra = train(a).unwrap();
    let rb = train(b).unwrap();
    assert_eq!(ra.losses.len(), rb.losses.len(), "{label}");
    for (i, (x, y)) in ra.losses.iter().zip(&rb.losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label} step {i}: {x} vs {y}");
    }
}

// ---------------------------------------------------------------------------
// Bitwise parity.
// ---------------------------------------------------------------------------

#[test]
fn zero_stages_match_zero0_bitwise_single_stage_dp2() {
    if !have_artifacts() {
        return;
    }
    let mut a = base(6);
    a.n_b = 2;
    for z in 1..=3u8 {
        let mut b = a.clone();
        b.zero = z;
        assert_bitwise_loss_match(&a, &b, &format!("zero={z}"));
    }
}

#[test]
fn zero_stages_match_zero0_bitwise_across_pipeline_dp_tp() {
    if !have_artifacts() {
        return;
    }
    // tiny has 2 layers: modular pipeline x data parallel x (emulated)
    // tensor parallel — the emulation is bitwise-exact, so the whole
    // combo must stay bitwise too.
    for (n_l, n_b, tp) in [(2usize, 2usize, 1usize), (1, 2, 2), (2, 2, 2)] {
        let mut a = base(4);
        a.n_l = n_l;
        a.n_b = n_b;
        a.tp = tp;
        a.force_tp_emulation = tp > 1;
        for z in 1..=3u8 {
            let mut b = a.clone();
            b.zero = z;
            assert_bitwise_loss_match(
                &a,
                &b,
                &format!("n_l={n_l} n_b={n_b} tp={tp} zero={z}"),
            );
        }
    }
}

#[test]
fn zero_is_inert_without_data_parallelism() {
    if !have_artifacts() {
        return;
    }
    // At dp = 1 there is no group to shard over: the schedule emits no
    // ZeRO ops and the run is the zero = 0 run.
    let mut cfg = base(2);
    cfg.zero = 2;
    let program = lower(&cfg.build_schedule(2)).expect("schedule lowers");
    assert_eq!(
        program.count(|o| {
            matches!(o, Op::ReduceScatterGrad { .. } | Op::AllGatherParams { .. })
        }),
        0
    );
    let mut plain = base(2);
    plain.zero = 0;
    assert_bitwise_loss_match(&plain, &cfg, "dp=1 zero=2");
    // At dp = 2 the ops appear.
    let mut dp = cfg.clone();
    dp.n_b = 2;
    let program = lower(&dp.build_schedule(2)).expect("schedule lowers");
    assert!(
        program.count(|o| {
            matches!(o, Op::ReduceScatterGrad { .. } | Op::AllGatherParams { .. })
        }) > 0
    );
}

// ---------------------------------------------------------------------------
// Measured state slope.
// ---------------------------------------------------------------------------

#[test]
fn zero_layer_state_shards_the_adam_moments_measured() {
    if !have_artifacts() {
        return;
    }
    let mut full = base(2);
    full.n_b = 2;
    let r0 = train(&full).unwrap();
    for z in 1..=3u8 {
        let mut sharded = full.clone();
        sharded.zero = z;
        let rz = train(&sharded).unwrap();
        // Params (4 B/param) stay replicated across the dp group in
        // this runtime (stage 3 gathers them before every use); the
        // Adam moments (8 B/param) split 1/dp: 12 -> 4 + 8/2 = 8.
        let ratio = rz.max_layer_state_bytes as f64 / r0.max_layer_state_bytes as f64;
        assert!(
            ratio > 0.64 && ratio < 0.70,
            "zero={z}: sharded layer state {} vs full {} (ratio {ratio:.4}, want ~2/3)",
            rz.max_layer_state_bytes,
            r0.max_layer_state_bytes
        );
        assert!(rz.max_state_bytes < r0.max_state_bytes, "zero={z}");
    }
    // dp = 1: nothing to shard, identical footprint.
    let mut solo = base(2);
    solo.zero = 2;
    let r1 = train(&solo).unwrap();
    let rbase = train(&base(2)).unwrap();
    assert_eq!(r1.max_layer_state_bytes, rbase.max_layer_state_bytes);
}

// ---------------------------------------------------------------------------
// Elastic resume across a zero change.
// ---------------------------------------------------------------------------

#[test]
fn zero2_to_zero0_resume_round_trips() {
    if !have_artifacts() {
        return;
    }
    let dir_down = std::env::temp_dir()
        .join(format!("lga_zero_resume_down_{}", std::process::id()));
    let dir_up = std::env::temp_dir()
        .join(format!("lga_zero_resume_up_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir_down);
    let _ = std::fs::remove_dir_all(&dir_up);

    // Uninterrupted zero = 0 reference (bitwise-equal to the zero = 2
    // trajectory by the parity tests above).
    let mut uninterrupted = base(6);
    uninterrupted.n_b = 2;
    let reference = train(&uninterrupted).unwrap();

    // zero = 2 -> zero = 0: the prefix streams [lo, hi) shard records
    // per dp rank; the resume assembles the complete cover back into
    // full state.
    let mut first = base(3);
    first.n_b = 2;
    first.zero = 2;
    first.offload = true;
    first.store_dir = Some(dir_down.clone());
    train(&first).unwrap();
    let mut second = base(6);
    second.n_b = 2;
    second.zero = 0;
    second.offload = true;
    second.store_dir = Some(dir_down.clone());
    second.resume = true;
    let rd = train(&second).unwrap();
    assert_eq!(rd.start_step, 3, "resume from the last complete step");
    for (i, (x, y)) in rd.losses.iter().zip(&reference.losses[3..]).enumerate() {
        assert!(
            (x - y).abs() < 1e-12,
            "zero 2->0 resumed step {}: {x} vs {y}",
            3 + i
        );
    }

    // zero = 0 -> zero = 2: full records re-slice to each rank's owned
    // Adam range.
    let mut first = base(3);
    first.n_b = 2;
    first.offload = true;
    first.store_dir = Some(dir_up.clone());
    train(&first).unwrap();
    let mut second = base(6);
    second.n_b = 2;
    second.zero = 2;
    second.offload = true;
    second.store_dir = Some(dir_up.clone());
    second.resume = true;
    let ru = train(&second).unwrap();
    assert_eq!(ru.start_step, 3, "resume from the last complete step");
    for (i, (x, y)) in ru.losses.iter().zip(&reference.losses[3..]).enumerate() {
        assert!(
            (x - y).abs() < 1e-12,
            "zero 0->2 resumed step {}: {x} vs {y}",
            3 + i
        );
    }

    let _ = std::fs::remove_dir_all(&dir_down);
    let _ = std::fs::remove_dir_all(&dir_up);
}
