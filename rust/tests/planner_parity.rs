//! Parity proofs for the planner/simulator perf refactor: the pruned,
//! parallel, cached fast paths must be *semantically identical* to the
//! straightforward references they replaced.
//!
//! 1. `search_fastest` (memory pre-filter + branch-and-bound + thread
//!    fan-out) selects the same plan as `search_fastest_exhaustive`
//!    (serial, full evaluation of every candidate), across strategy ×
//!    cluster.
//! 2. `simulate_program` with `record_timeline: false` reports
//!    bit-identical makespan / busy / peak memory to the recording path.
//! 3. Reusing one `SimScratch` across programs changes nothing.

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::planner::{search_fastest, search_fastest_exhaustive};
use lga_mpp::report::menu_for;
use lga_mpp::schedule::{lower, modular_pipeline, one_f_one_b, standard_ga, Op, ScheduleSpec};
use lga_mpp::sim::{
    simulate_program, simulate_program_into, simulate_program_opts, CostTable, SimOptions,
    SimScratch, Stream,
};

/// One search-parity comparison: pruned/parallel vs serial exhaustive.
fn assert_search_parity(cluster: &ClusterSpec, cname: &str, strategy: Strategy, x: usize) {
    let model = XModel::new(x);
    let menu = menu_for(strategy);
    let fast = search_fastest(&model, cluster, strategy, menu);
    let slow = search_fastest_exhaustive(&model, cluster, strategy, menu);
    let tag = format!("{cname}/{strategy:?}/X_{x}");
    match (fast, slow) {
        (None, None) => {}
        (Some(a), Some(b)) => {
            assert_eq!(a.cfg, b.cfg, "{tag}: different plan selected");
            let (ta, tb) = (a.speed.training_secs, b.speed.training_secs);
            assert!((ta - tb).abs() <= 1e-9 * tb.max(1.0), "{tag}: training_secs {ta} vs {tb}");
            assert_eq!(
                a.memory.total().to_bits(),
                b.memory.total().to_bits(),
                "{tag}: memory breakdown diverged"
            );
        }
        (a, b) => panic!(
            "{tag}: feasibility disagrees (fast {:?}, exhaustive {:?})",
            a.map(|p| p.cfg),
            b.map(|p| p.cfg)
        ),
    }
}

#[test]
fn pruned_parallel_search_matches_serial_exhaustive_everywhere() {
    // Full strategy matrix at X_32 (keeps the debug-mode `cargo test`
    // run quick — the exhaustive reference is unpruned by design).
    let clusters = [
        (ClusterSpec::reference(), "reference"),
        (ClusterSpec::ethernet(), "ethernet"),
        (ClusterSpec::unlimited_node(), "unlimited_node"),
    ];
    for (cluster, cname) in &clusters {
        for strategy in Strategy::ALL {
            assert_search_parity(cluster, cname, strategy, 32);
        }
    }
    // One deep-grid case (the figure sweeps' heaviest single search);
    // CI re-runs this whole test in release mode as the smoke step.
    assert_search_parity(&ClusterSpec::reference(), "reference", Strategy::Improved, 108);
}

fn cost_table(n_b: usize, n_l: usize, n_mu: usize, partition: bool) -> CostTable {
    let cfg = TrainConfig {
        strategy: if partition { Strategy::Improved } else { Strategy::Baseline },
        n_b,
        n_l,
        n_a: 1,
        n_mu,
        b_mu: 1.0,
        offload: false,
        partition,
        zero: 0,
    };
    CostTable::new(&XModel::new(32).shape(), &cfg, &ClusterSpec::reference())
}

#[test]
fn timeline_off_reports_bit_identical_metrics() {
    // Planner-relevant shapes, including the X_160 snap and a deep case.
    let shapes: [(usize, usize, usize, bool); 4] =
        [(16, 4, 8, false), (64, 8, 16, true), (160, 5, 32, true), (128, 32, 128, false)];
    for (d_l, n_l, n_mu, partition) in shapes {
        let spec = ScheduleSpec {
            d_l,
            n_l,
            n_mu,
            tp: 1,
            partition,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
        let costs = cost_table(8, n_l, n_mu, partition);
        for schedule in [modular_pipeline(&spec), standard_ga(&spec), one_f_one_b(&spec)] {
            let program = lower(&schedule).expect("generated schedules lower");
            let on = simulate_program(&program, &costs);
            let off =
                simulate_program_opts(&program, &costs, SimOptions { record_timeline: false });
            let tag = format!("{} {d_l}L/{n_l}S/{n_mu}mb", program.name);
            assert_eq!(on.makespan.to_bits(), off.makespan.to_bits(), "{tag}: makespan");
            assert_eq!(on.busy.len(), off.busy.len(), "{tag}: busy len");
            for (i, (a, b)) in on.busy.iter().zip(&off.busy).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: busy[{i}]");
            }
            assert_eq!(on.peak_memory.len(), off.peak_memory.len(), "{tag}: peak len");
            for (i, (a, b)) in on.peak_memory.iter().zip(&off.peak_memory).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: peak_memory[{i}]");
            }
            assert_eq!(
                on.compute_efficiency().to_bits(),
                off.compute_efficiency().to_bits(),
                "{tag}: efficiency"
            );
            assert!(off.timeline.is_empty(), "{tag}: timeline should be skipped");
            assert_eq!(on.timeline.len(), program.len(), "{tag}: full timeline expected");
        }
    }
}

/// A cost table for an offload-only configuration (no partition): the
/// branch of `CostTable::restore_params` that no generated schedule
/// could previously reach.
fn offload_cost_table(n_l: usize, n_mu: usize) -> CostTable {
    let cfg = TrainConfig {
        strategy: Strategy::Improved,
        n_b: 1,
        n_l,
        n_a: 1,
        n_mu,
        b_mu: 1.0,
        offload: true,
        partition: false,
        zero: 0,
    };
    CostTable::new(&XModel::new(32).shape(), &cfg, &ClusterSpec::reference())
}

#[test]
fn offload_only_specs_emit_and_charge_restores_and_stores() {
    // Schedule/sim parity for §8.2: an offload && !partition spec emits
    // RestoreParams + OffloadStore, and the simulator charges both
    // (restore_params on the inbound stream, offload_store on the CPU
    // link) — none of which was reachable before the offload flag.
    let spec = ScheduleSpec {
        d_l: 16,
        n_l: 4,
        n_mu: 8,
        tp: 1,
        partition: false,
        offload: true,
        data_parallel: false,
        zero: 0,
    };
    let costs = offload_cost_table(4, 8);
    assert!(costs.restore_params > 0.0, "offload restores must not be free");
    assert!(costs.offload_store > 0.0, "offload stores must not be free");
    let mut base = spec;
    base.offload = false;
    for (with, without) in [
        (modular_pipeline(&spec), modular_pipeline(&base)),
        (standard_ga(&spec), standard_ga(&base)),
        (one_f_one_b(&spec), one_f_one_b(&base)),
    ] {
        let program = lower(&with).expect("offload schedules lower");
        assert!(program.count(|o| matches!(o, Op::RestoreParams { .. })) > 0, "{}", program.name);
        assert_eq!(program.count(|o| matches!(o, Op::OffloadStore { .. })), 16, "{}", program.name);
        let r = simulate_program(&program, &costs);
        let netin: f64 = (0..4).map(|s| r.stream_busy(s, Stream::NetIn)).sum();
        let cpu: f64 = (0..4).map(|s| r.stream_busy(s, Stream::CpuLink)).sum();
        assert!(netin > 0.0, "{}: restores uncharged", program.name);
        assert!(cpu > 0.0, "{}: stores uncharged", program.name);
        // And the offload ops cost real time vs the same policy without.
        let r0 = simulate_program(&lower(&without).unwrap(), &costs);
        assert!(r.makespan >= r0.makespan, "{}", program.name);
    }
}

#[test]
fn non_offload_programs_are_unchanged() {
    // The offload flag must be strictly additive: with it off, every
    // policy lowers to the same op multiset as before the flag existed —
    // no stores, and restores only under a partition.
    for partition in [false, true] {
        let spec = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 8,
            tp: 1,
            partition,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
        for schedule in [modular_pipeline(&spec), standard_ga(&spec), one_f_one_b(&spec)] {
            let p = lower(&schedule).expect("lowers");
            assert_eq!(p.count(|o| matches!(o, Op::OffloadStore { .. })), 0, "{}", p.name);
            let restores = p.count(|o| matches!(o, Op::RestoreParams { .. }));
            if partition {
                assert!(restores > 0, "{}", p.name);
            } else {
                assert_eq!(restores, 0, "{}", p.name);
            }
            assert!(!p.offloaded);
        }
    }
}

#[test]
fn tp_plans_are_selected_only_when_beneficial() {
    // The tensor-parallel axis must earn its place: whenever the search
    // picks n_a > 1, either the best tp = 1 plan is slower (the tp
    // all-reduce overhead is bought back by the 1/tp per-rank compute),
    // or no tp = 1 plan fits device memory at all. Checked on the cost
    // model's own metric (the search's selection criterion) and
    // cross-checked on simulated time via the tp-pinned search.
    use lga_mpp::planner::{search_fastest_tp, simulate_plan};
    use lga_mpp::report::menu_for;

    let cluster = ClusterSpec::reference();
    for x in [32usize, 108] {
        let model = XModel::new(x);
        for strategy in Strategy::ALL {
            let menu = menu_for(strategy);
            if !menu.tensor {
                continue;
            }
            let Some(best) = search_fastest(&model, &cluster, strategy, menu) else {
                continue;
            };
            if best.cfg.n_a == 1 {
                continue;
            }
            let tag = format!("{strategy:?}/X_{x}");
            match search_fastest_tp(&model, &cluster, strategy, menu, Some(1)) {
                None => {} // no tp = 1 plan fits: tp is required
                Some(tp1) => {
                    // <= up to the selection fold's tie band (a tied
                    // non-offloaded plan may displace the incumbent).
                    assert!(
                        best.speed.training_secs <= tp1.speed.training_secs * (1.0 + 2e-4),
                        "{tag}: tp = {} plan ({:.3e}s) does not beat tp = 1 ({:.3e}s)",
                        best.cfg.n_a,
                        best.speed.training_secs,
                        tp1.speed.training_secs
                    );
                    // Simulated (executed-schedule) time agrees on the
                    // ordering within the sim-vs-closed-form modelling
                    // slack (the sim adds overlap effects the closed
                    // forms approximate; the existing simloop tests
                    // bound the gap at ~25%).
                    let sb = simulate_plan(&model, &cluster, &best);
                    let s1 = simulate_plan(&model, &cluster, &tp1);
                    assert!(
                        sb.secs_per_sequence <= s1.secs_per_sequence * 1.25,
                        "{tag}: simulated ranking contradicts the tp choice \
                         ({:.3e} vs {:.3e} s/seq)",
                        sb.secs_per_sequence,
                        s1.secs_per_sequence
                    );
                }
            }
        }
    }
}

#[test]
fn tp_pinned_search_agrees_with_the_unrestricted_grid() {
    // Pinning --tp to the winner's degree must reproduce the winner
    // exactly (the filter preserves enumeration order), and pinning to
    // tp = 1 must equal a tensor-free menu search.
    use lga_mpp::costmodel::ParallelismMenu;
    use lga_mpp::planner::search_fastest_tp;

    let cluster = ClusterSpec::reference();
    let model = XModel::new(64);
    let menu = ParallelismMenu::THREE_D;
    let best = search_fastest(&model, &cluster, Strategy::Improved, menu).expect("plan");
    let pinned =
        search_fastest_tp(&model, &cluster, Strategy::Improved, menu, Some(best.cfg.n_a))
            .expect("pinned plan");
    // The winner is in the pinned subset, so the pinned search can do no
    // worse than it (tie-band slack: removing other-degree candidates
    // can reshuffle within-band tie-breaks).
    assert_eq!(pinned.cfg.n_a, best.cfg.n_a);
    assert!(
        pinned.speed.training_secs <= best.speed.training_secs * (1.0 + 2e-4),
        "{} vs {}",
        pinned.speed.training_secs,
        best.speed.training_secs
    );

    let tp1 = search_fastest_tp(&model, &cluster, Strategy::Improved, menu, Some(1));
    let no_tensor =
        search_fastest(&model, &cluster, Strategy::Improved, ParallelismMenu::DATA_PIPE);
    match (tp1, no_tensor) {
        (Some(a), Some(b)) => assert_eq!(a.cfg, b.cfg),
        (a, b) => panic!("feasibility disagrees: {:?} vs {:?}", a.map(|p| p.cfg), b.map(|p| p.cfg)),
    }
}

#[test]
fn calibrated_link_changes_wire_costs_and_plan_pricing() {
    // Closing the performance-truth loop: a `repro netbench` calibration
    // attached to the cluster must actually reprice wire ops in the
    // simulator's cost table AND the planner's closed-form estimate —
    // measured figures, not spec sheets.
    use lga_mpp::costmodel::estimate;
    use lga_mpp::hardware::NetCalibration;

    let quoted = ClusterSpec::reference();
    let cal = NetCalibration {
        bandwidth_bytes_per_s: quoted.inter_node_bandwidth() / 8.0,
        rtt_secs: 2.0e-4,
    };
    let measured = quoted.with_calibration(cal);
    assert!(measured.inter_node_threshold() > quoted.inter_node_threshold());

    // Simulator pricing: every inter-node wire op gets strictly more
    // expensive on the measured (slower, non-zero-latency) link.
    let cfg = TrainConfig {
        strategy: Strategy::Improved,
        n_b: 8,
        n_l: 4,
        n_a: 1,
        n_mu: 8,
        b_mu: 1.0,
        offload: false,
        partition: true,
        zero: 0,
    };
    let shape = XModel::new(32).shape();
    let tq = CostTable::new(&shape, &cfg, &quoted);
    let tm = CostTable::new(&shape, &cfg, &measured);
    assert!(tm.send_act > tq.send_act, "{} vs {}", tm.send_act, tq.send_act);
    assert!(tm.reduce_grad > tq.reduce_grad, "{} vs {}", tm.reduce_grad, tq.reduce_grad);
    assert!(
        tm.restore_params >= tq.restore_params,
        "{} vs {}",
        tm.restore_params,
        tq.restore_params
    );

    // Planner pricing: the network-bound Table 6.1 baseline-3d row gets
    // a strictly worse efficiency and training time on the measured
    // wire (in-node NVLink tensor parallelism stays untouched).
    let model = XModel::x160();
    let net_bound = TrainConfig {
        strategy: Strategy::Baseline,
        n_b: 14,
        n_l: 160,
        n_a: 16,
        n_mu: 172,
        b_mu: 1.0,
        offload: false,
        partition: false,
        zero: 0,
    };
    let eq = estimate(&model, &net_bound, &quoted);
    let em = estimate(&model, &net_bound, &measured);
    assert!(
        em.efficiency < eq.efficiency,
        "calibration did not reach the planner: {} vs {}",
        em.efficiency,
        eq.efficiency
    );
    assert!(em.training_secs > eq.training_secs);
    assert!(
        em.overheads.tensor_parallel == eq.overheads.tensor_parallel,
        "n_a = 16 fits the node: NVLink pricing must not move"
    );
}

#[test]
fn scratch_reuse_across_programs_changes_nothing() {
    let spec_a = ScheduleSpec {
        d_l: 64,
        n_l: 8,
        n_mu: 16,
        tp: 1,
        partition: true,
        offload: false,
        data_parallel: true,
        zero: 0,
    };
    let spec_b = ScheduleSpec {
        d_l: 16,
        n_l: 4,
        n_mu: 8,
        tp: 1,
        partition: false,
        offload: false,
        data_parallel: true,
        zero: 0,
    };
    let prog_a = lower(&modular_pipeline(&spec_a)).unwrap();
    let prog_b = lower(&standard_ga(&spec_b)).unwrap();
    let costs_a = cost_table(8, 8, 16, true);
    let costs_b = cost_table(8, 4, 8, false);
    let ref_a = simulate_program(&prog_a, &costs_a);
    let ref_b = simulate_program(&prog_b, &costs_b);

    let opts = SimOptions { record_timeline: false };
    let mut scratch = SimScratch::new();
    // Interleave programs of different sizes through one scratch: results
    // must not depend on what ran before.
    for _ in 0..3 {
        let a = simulate_program_into(&prog_a, &costs_a, opts, &mut scratch);
        assert_eq!(a.makespan.to_bits(), ref_a.makespan.to_bits());
        assert_eq!(a.busy, ref_a.busy);
        assert_eq!(a.peak_memory, ref_a.peak_memory);
        scratch.recycle(a);
        let b = simulate_program_into(&prog_b, &costs_b, opts, &mut scratch);
        assert_eq!(b.makespan.to_bits(), ref_b.makespan.to_bits());
        assert_eq!(b.busy, ref_b.busy);
        assert_eq!(b.peak_memory, ref_b.peak_memory);
        scratch.recycle(b);
    }
}

#[test]
fn zero_pinned_search_preserves_parity_and_unlocks_memory_bound_configs() {
    // The zero axis must not disturb the frozen legacy grid: pinning
    // zero = 0 (or not pinning) is exactly the unrestricted search.
    // Pinning zero > 0 re-prices the same grid with the optimizer
    // state sharded 1/dp — which makes memory-bound configurations
    // feasible that no full-state plan can inhabit.
    use lga_mpp::costmodel::{MemoryBreakdown, ParallelismMenu};
    use lga_mpp::planner::{search_fastest_zero, statically_valid};

    let cluster = ClusterSpec::reference();
    let model = XModel::new(64);
    let menu = ParallelismMenu::THREE_D;
    let legacy = search_fastest(&model, &cluster, Strategy::Improved, menu);
    let z0 = search_fastest_zero(&model, &cluster, Strategy::Improved, menu, Some(0));
    let unpinned = search_fastest_zero(&model, &cluster, Strategy::Improved, menu, None);
    assert_eq!(legacy.as_ref().map(|p| p.cfg), z0.map(|p| p.cfg));
    assert_eq!(legacy.map(|p| p.cfg), unpinned.map(|p| p.cfg));

    // X_58 on the data-parallel-only menu is memory-bound: zero = 0
    // shards nothing, so the 12 B/param state (~88 GiB) exceeds the
    // 80 GiB device at *any* dp, while ZeRO-2 splits the moments 1/dp
    // and fits.
    let model = XModel::new(58);
    let menu = ParallelismMenu::DATA;
    let plan = search_fastest_zero(&model, &cluster, Strategy::Improved, menu, Some(2))
        .expect("a zero-2 plan fits the memory-bound config");
    assert_eq!(plan.cfg.zero, 2);
    assert!(!plan.cfg.partition, "the two state shardings are mutually exclusive");
    assert!(plan.cfg.n_b > 1, "sharding needs a dp group");
    let budget = cluster.gpu.memory_bytes;
    let m2 = MemoryBreakdown::evaluate(&model.shape(), &plan.cfg);
    assert!(m2.gpu_resident(plan.cfg.offload) <= budget);
    // The identical shape without the sharding cannot live on the
    // device (offload aside â the point is the resident state).
    let m0 = MemoryBreakdown::evaluate(&model.shape(), &TrainConfig { zero: 0, ..plan.cfg });
    assert!(
        m0.gpu_resident(false) > budget,
        "zero = 0 resident {:.1} GiB should exceed the {:.1} GiB device",
        m0.gpu_resident(false) / (1u64 << 30) as f64,
        budget / (1u64 << 30) as f64
    );
    // And the selected plan proves out under the whole-world static
    // verifier â the same checks `repro verify` runs before launch.
    statically_valid(&model, &cluster, &plan).expect("zero plan verifies clean");
}
