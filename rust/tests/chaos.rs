//! Fault-injected elastic training (`repro chaos`), end to end.
//!
//! Three layers:
//! * schedule-free: the seeded fault schedule is deterministic and
//!   respects the resume contract (runs everywhere);
//! * simulator: failure/restart accounting on a lowered offloaded
//!   program — streamed (interval-1) checkpoints lose strictly less
//!   work than classic intervals, and the planner's expected-lost-work
//!   bound covers a replayed failure draw (runs everywhere);
//! * runtime: a chaos run with two rank kills (one changing the dp/tp
//!   topology on revival) and a torn checkpoint store must land on the
//!   same loss trajectory as an uninterrupted reference run. Needs the
//!   PJRT artifacts (`make artifacts`); skips gracefully without them.

use std::path::PathBuf;

use lga_mpp::costmodel::{ParallelismMenu, Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::optim::LrSchedule;
use lga_mpp::planner::{
    lost_work_bound, lower_plan, plan_with_reliability, search_fastest_tp, Plan,
    ReliabilityParams,
};
use lga_mpp::schedule::{lower, modular_pipeline, ScheduleProgram, ScheduleSpec};
use lga_mpp::sim::{recovery_costs, simulate_with_failures, CostTable, FailureEvent};
use lga_mpp::trainer::{
    run_chaos, seeded_plan, ChaosEvent, ChaosPlan, Policy, Revive, TrainerConfig,
};

// ---------------------------------------------------------------------------
// seeded schedule
// ---------------------------------------------------------------------------

#[test]
fn the_seeded_fault_schedule_is_deterministic_and_contract_safe() {
    assert_eq!(seeded_plan(7, 40, 2, 2, 2), seeded_plan(7, 40, 2, 2, 2));
    let p = seeded_plan(7, 40, 2, 2, 2);
    assert_eq!(p.events.len(), 3, "2 kills + 1 torn store");
    assert!(p.events.windows(2).all(|w| w[0].at_step() <= w[1].at_step()));
    for e in &p.events {
        assert!(e.at_step() >= 1 && e.at_step() < 40, "{e:?}");
        if let ChaosEvent::Kill { revive, .. } = e {
            assert_eq!(revive.n_b * revive.n_mu, 4, "revive must preserve the global batch");
        }
    }
    // Different seeds produce different schedules (the rng is not a
    // constant function).
    let plans: Vec<ChaosPlan> = (0..8).map(|s| seeded_plan(s, 40, 2, 2, 2)).collect();
    assert!(plans.iter().any(|p| *p != plans[0]));
}

// ---------------------------------------------------------------------------
// simulator failure accounting
// ---------------------------------------------------------------------------

fn offloaded_program() -> (ScheduleProgram, CostTable) {
    let spec = ScheduleSpec {
        d_l: 8,
        n_l: 4,
        n_mu: 4,
        tp: 1,
        partition: true,
        offload: true,
        data_parallel: true,
        zero: 0,
    };
    let cfg = TrainConfig {
        strategy: Strategy::Improved,
        n_b: 2,
        n_l: 4,
        n_a: 1,
        n_mu: 4,
        b_mu: 1.0,
        offload: true,
        partition: true,
        zero: 0,
    };
    let costs = CostTable::new(&XModel::new(32).shape(), &cfg, &ClusterSpec::reference());
    let p = lower(&modular_pipeline(&spec)).expect("offloaded modular pipeline lowers");
    (p, costs)
}

#[test]
fn streamed_checkpoints_lose_less_work_than_classic_intervals() {
    let (p, costs) = offloaded_program();
    let (step, restore) = recovery_costs(&p, &costs);
    assert!(step > 0.0 && restore > 0.0);
    // Failures every ~9.4 steps: shorter than the classic 16-step
    // checkpoint interval, so the classic job keeps rolling back past
    // its last durable point while the streamed job only re-runs the
    // in-flight step.
    let events: Vec<FailureEvent> =
        (1..=6).map(|k| FailureEvent { at_secs: k as f64 * 9.4 * step, stage: 0 }).collect();
    let streamed = simulate_with_failures(&p, &costs, 64, 1, &events);
    let classic = simulate_with_failures(&p, &costs, 64, 16, &events);
    assert_eq!(streamed.failures.len(), 6);
    assert_eq!(classic.failures.len(), 6);
    assert!(streamed.failures.iter().all(|f| f.rolled_back_steps == 0));
    assert!(classic.failures.iter().any(|f| f.rolled_back_steps > 0));
    // Every failure charges at least the restore, and the per-failure
    // records account for exactly the total lost time.
    assert!(streamed.failures.iter().all(|f| f.lost_secs >= restore));
    let sum: f64 = streamed.failures.iter().map(|f| f.lost_secs).sum();
    assert!((sum - streamed.lost_secs).abs() <= 1e-9 * streamed.lost_secs.max(1.0));
    assert!(streamed.lost_secs < classic.lost_secs);
    assert!(streamed.lost_fraction < classic.lost_fraction);
}

#[test]
fn the_planner_bound_matches_its_own_recovery_costs() {
    let model = XModel::new(32);
    let cluster = ClusterSpec::reference();
    let rel = ReliabilityParams { mtbf_hours: 200.0, max_lost_work: 1.0 };
    let rp = plan_with_reliability(
        &model,
        &cluster,
        Strategy::Improved,
        ParallelismMenu::THREE_D,
        &rel,
    )
    .expect("a 100% budget rejects nothing feasible");
    // The CLI-visible bound must be exactly λ_job · (restore +
    // interval · step) of the winner's lowered schedule.
    let (cfg, prog) = lower_plan(&model, &rp.sim.plan);
    let costs = CostTable::new(&model.shape(), &cfg, &cluster);
    let (step_secs, restore_secs) = recovery_costs(&prog, &costs);
    let lambda = cfg.n_gpu() as f64 / (rel.mtbf_hours * 3600.0);
    let want = lambda * (restore_secs + rp.bound.ckpt_interval as f64 * step_secs);
    assert!(
        (rp.bound.fraction - want).abs() <= 1e-12 * want,
        "bound {} vs recomputed {want}",
        rp.bound.fraction
    );
    assert!((rp.bound.step_secs - step_secs).abs() <= 1e-12 * step_secs);
    assert!((rp.bound.restore_secs - restore_secs).abs() <= 1e-12 * restore_secs.max(1e-300));
}

#[test]
fn the_reliability_bound_covers_a_simulated_failure_draw() {
    let model = XModel::new(32);
    let cluster = ClusterSpec::reference();
    let seed =
        search_fastest_tp(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D, None)
            .expect("the reference cluster plans X_32");
    // The streamed-checkpoint (offloaded) variant: checkpoint interval
    // 1, restore cost from the schedule's real RestoreParams volume.
    let plan = Plan::build_pub(&model, TrainConfig { offload: true, ..seed.cfg }, &cluster);
    let (cfg, prog) = lower_plan(&model, &plan);
    let costs = CostTable::new(&model.shape(), &cfg, &cluster);
    let (step_secs, restore_secs) = recovery_costs(&prog, &costs);
    assert!(step_secs > 0.0 && restore_secs > 0.0);

    // Pick the MTBF so failures arrive every ~25 steps, then check the
    // planner's bound at that MTBF against a replayed draw. Golden-ratio
    // phase spacing equidistributes the in-flight offsets, so the draw
    // is a fair sample, not a best or worst case.
    let mean_gap = 25.0 * step_secs;
    let mtbf_hours = cfg.n_gpu() as f64 * mean_gap / 3600.0;
    let rel = ReliabilityParams { mtbf_hours, max_lost_work: 1.0 };
    let bound = lost_work_bound(&model, &cluster, &plan, &rel);
    assert_eq!(bound.ckpt_interval, 1, "offloaded plans stream durable checkpoints every step");

    let n_events = 40usize;
    let mut t = 0.0f64;
    let mut events = Vec::with_capacity(n_events);
    for k in 0..n_events {
        let phase = (k as f64 * 0.618_033_988_749_894_9).fract();
        t += mean_gap * (0.5 + phase);
        events.push(FailureEvent { at_secs: t, stage: 0 });
    }
    let steps = (1.25 * t / step_secs).ceil() as usize;
    let acc = simulate_with_failures(&prog, &costs, steps, bound.ckpt_interval, &events);
    assert_eq!(acc.failures.len(), n_events, "every drawn failure lands inside the job");

    // The bound charges every failure the worst case (a full interval
    // plus the restore), so the replayed draw must land under it — and
    // a fair draw should not land absurdly under it either.
    let lambda_actual = acc.failures.len() as f64 / acc.wall_secs;
    let per_failure_worst = restore_secs + bound.ckpt_interval as f64 * step_secs;
    assert!(acc.lost_fraction <= lambda_actual * per_failure_worst * (1.0 + 1e-9));
    assert!(acc.lost_fraction >= 0.2 * lambda_actual * per_failure_worst);
    // The draw's actual rate never exceeds the planner's assumed rate
    // (lost time only stretches the wall), so the CLI-visible bound
    // covers the replay too.
    assert!(acc.lost_fraction <= bound.fraction);
}

// ---------------------------------------------------------------------------
// fault-injected training vs uninterrupted reference (needs artifacts)
// ---------------------------------------------------------------------------

fn have_artifacts() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny/manifest.json").exists()
}

fn chaos_config(store: PathBuf) -> TrainerConfig {
    let mut c = TrainerConfig::quick("tiny");
    c.steps = 9;
    c.n_b = 2;
    c.n_mu = 2;
    c.policy = Policy::Improved;
    c.partition = true;
    c.offload = true;
    c.store_dir = Some(store);
    c.lr = LrSchedule::constant(3e-3);
    c
}

#[test]
fn chaos_run_matches_the_uninterrupted_reference() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join(format!("lga_chaos_{}", std::process::id()));
    // Two rank kills — the first revives on a *different* dp/tp
    // topology (2-way dp → 1-way dp with 2-way tp), the second revives
    // back — plus a checkpoint torn mid-write at the same step as the
    // first kill, so that resume must fall back one step and re-run it.
    let plan = ChaosPlan {
        seed: 0,
        events: vec![
            ChaosEvent::Kill { at_step: 3, rank: 0, revive: Revive { n_b: 1, n_mu: 4, tp: 2 } },
            ChaosEvent::TearStore { at_step: 3 },
            ChaosEvent::Kill { at_step: 6, rank: 1, revive: Revive { n_b: 2, n_mu: 2, tp: 1 } },
        ],
    };
    let r = run_chaos(&chaos_config(dir.clone()), &plan).expect("chaos run");
    assert_eq!(r.kills, 2);
    assert_eq!(r.torn_stores, 1);
    assert_eq!(r.topology_changes, 2, "both revives change the running topology");
    assert!(r.tp_resharded, "the first revive re-shards tensor parallelism");
    assert_eq!(r.reference.len(), 9);
    assert_eq!(r.chaos.len(), 9);
    assert!(r.chaos.iter().all(|l| l.is_finite()), "every step must be covered: {:?}", r.chaos);
    assert!(
        r.max_abs_diff < r.tolerance(),
        "chaos diverged from the uninterrupted reference: {} >= {} (ref {:?} vs chaos {:?})",
        r.max_abs_diff,
        r.tolerance(),
        r.reference,
        r.chaos
    );
    let _ = std::fs::remove_dir_all(&dir);
    let mut sib = dir.into_os_string();
    sib.push("_reference");
    let _ = std::fs::remove_dir_all(PathBuf::from(sib));
}
