//! Whole-world static analyzer tests: adversarial mutations must be
//! rejected with diagnostics naming the offending rank and op, every
//! generated world must be accepted, and the planner's static filter
//! must not change which plan the search selects.

use lga_mpp::analysis::{verify_program, MemoryModel, WorldError, WorldModel};
use lga_mpp::collective::{Rank, Topology};
use lga_mpp::costmodel::{MemoryBreakdown, Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::planner::{
    fastest_plan, rank_by_simulation, search_fastest, simulate_plan, statically_valid, Plan,
    SimulatedPlan,
};
use lga_mpp::report::menu_for;
use lga_mpp::schedule::{
    interleaved_1f1b, interleaved_applicable, layered_ga, lower, modular_pipeline, one_f_one_b,
    standard_ga, Op, Schedule, ScheduleProgram, ScheduleSpec,
};
use lga_mpp::sim::{CostTable, WireBytes};

fn spec(d_l: usize, n_l: usize, n_mu: usize, tp: usize) -> ScheduleSpec {
    ScheduleSpec {
        d_l,
        n_l,
        n_mu,
        tp,
        partition: false,
        offload: false,
        data_parallel: true,
        zero: 0,
    }
}

fn program(s: &Schedule) -> ScheduleProgram {
    lower(s).expect("generated schedules lower")
}

fn costs_for(sp: &ScheduleSpec, dp: usize) -> CostTable {
    let cfg = TrainConfig {
        strategy: Strategy::Improved,
        n_b: dp,
        n_l: sp.n_l,
        n_a: sp.tp,
        n_mu: sp.n_mu,
        b_mu: 1.0,
        offload: sp.offload,
        partition: sp.partition,
        zero: 0,
    };
    CostTable::new(&XModel::new(32).shape(), &cfg, &ClusterSpec::reference())
}

// ---- mutation class 1: dropped receive ---------------------------------

#[test]
fn dropped_recv_is_rejected_naming_the_channel() {
    let sp = spec(16, 4, 8, 1);
    let prog = program(&modular_pipeline(&sp));
    let topo = Topology::new(4, 1, 1);
    let mut world = WorldModel::compose(&prog, topo, WireBytes::default()).unwrap();
    assert!(world.verify(None).is_empty(), "unmutated world must be clean");

    let victim = topo.index(Rank { stage: 1, dp: 0, tp: 0 });
    let pos = world
        .find_op(victim, |op| matches!(op, Op::RecvAct { .. }))
        .expect("stage 1 receives activations");
    let dropped = world.remove_op(victim, pos);
    assert!(matches!(dropped, Op::RecvAct { .. }));

    let errors = world.verify(None);
    let p2p = errors
        .iter()
        .find_map(|e| match e {
            WorldError::P2p { from, to, .. } => Some((from, to)),
            _ => None,
        })
        .unwrap_or_else(|| panic!("expected a p2p error, got {errors:?}"));
    // The diagnostic names the exact channel: sender stage 0, starved
    // receiver stage 1.
    assert_eq!((p2p.0.stage, p2p.1.stage), (0, 1));
    let msg = errors.iter().map(|e| e.to_string()).collect::<Vec<_>>().join("\n");
    assert!(msg.contains("rank(stage 0") && msg.contains("rank(stage 1"), "{msg}");
}

// ---- mutation class 2: reordered collective ----------------------------

#[test]
fn reordered_tensor_all_reduce_is_rejected_naming_the_rank() {
    let sp = spec(16, 4, 8, 2);
    let prog = program(&modular_pipeline(&sp));
    let topo = Topology::new(4, 1, 2);
    let mut world = WorldModel::compose(&prog, topo, WireBytes::default()).unwrap();
    assert!(world.verify(None).is_empty(), "unmutated world must be clean");

    // Swap one rank's first two TensorAllReduce ops: its tp ring peers
    // now issue a different sequence — the classic whole-ring hang.
    let victim = topo.index(Rank { stage: 2, dp: 0, tp: 1 });
    let tars: Vec<usize> = world.ranks[victim]
        .ops
        .iter()
        .enumerate()
        .filter(|(_, op)| matches!(op, Op::TensorAllReduce { .. }))
        .map(|(i, _)| i)
        .collect();
    assert!(tars.len() >= 2, "need two collectives to reorder");
    assert_ne!(
        world.ranks[victim].ops[tars[0]].to_string(),
        world.ranks[victim].ops[tars[1]].to_string()
    );
    world.swap_ops(victim, tars[0], tars[1]);

    let errors = world.verify(None);
    let bad = errors
        .iter()
        .find_map(|e| match e {
            WorldError::Collective { axis, b, index, .. } => Some((*axis, b, *index)),
            _ => None,
        })
        .unwrap_or_else(|| panic!("expected a collective error, got {errors:?}"));
    assert_eq!(bad.0, "tp");
    assert_eq!(*bad.1, Rank { stage: 2, dp: 0, tp: 1 });
    assert_eq!(bad.2, 0, "divergence is at the first swapped instance");
    let msg = errors.iter().map(|e| e.to_string()).collect::<Vec<_>>().join("\n");
    assert!(msg.contains("rank(stage 2, dp 0, tp 1)"), "{msg}");
}

// ---- mutation class 3: payload size mismatch ---------------------------

#[test]
fn undersized_payload_is_rejected_naming_peer_and_counts() {
    let sp = spec(16, 4, 8, 1);
    let prog = program(&modular_pipeline(&sp));
    let topo = Topology::new(4, 1, 1);
    let wire = costs_for(&sp, 1).wire;
    assert!(wire.send_act > 0.0);
    let mut world = WorldModel::compose(&prog, topo, wire).unwrap();
    assert!(world.verify(None).is_empty(), "unmutated world must be clean");

    // Stage 0 halves what it puts on the activation wire.
    let victim = topo.index(Rank { stage: 0, dp: 0, tp: 0 });
    world.ranks[victim].wire.send_act /= 2.0;

    let errors = world.verify(None);
    let pay = errors
        .iter()
        .find_map(|e| match e {
            WorldError::Payload { from, to, sent_elems, expected_elems, .. } => {
                Some((from, to, *sent_elems, *expected_elems))
            }
            _ => None,
        })
        .unwrap_or_else(|| panic!("expected a payload error, got {errors:?}"));
    assert_eq!((pay.0.stage, pay.1.stage), (0, 1));
    assert!((pay.2 - pay.3 / 2.0).abs() < 1e-9, "sender halved: {} vs {}", pay.2, pay.3);
    let msg = errors.iter().map(|e| e.to_string()).collect::<Vec<_>>().join("\n");
    assert!(msg.contains("rank(stage 0") && msg.contains("elements"), "{msg}");
}

// ---- mutation class 4: memory overflow ---------------------------------

#[test]
fn overfull_stage_is_rejected_naming_rank_and_op() {
    let sp = spec(16, 4, 8, 1);
    let prog = program(&standard_ga(&sp));
    let topo = Topology::new(4, 1, 1);
    let world = WorldModel::compose(&prog, topo, WireBytes::default()).unwrap();

    // A budget the stashed checkpoints cannot fit: standard GA holds
    // every forward's checkpoint at once (4 layers x 8 micro-batches).
    let tiny = MemoryModel {
        budget: 10.0,
        state_bytes: 4.0,
        checkpoint_bytes: 3.0,
        payload_bytes: 1.0,
        live_bytes: 2.0,
    };
    let errors = world.verify(Some(&tiny));
    let mem = errors
        .iter()
        .find_map(|e| match e {
            WorldError::Memory { rank, op, peak_bytes, budget_bytes, .. } => {
                Some((rank, op, *peak_bytes, *budget_bytes))
            }
            _ => None,
        })
        .unwrap_or_else(|| panic!("expected a memory error, got {errors:?}"));
    assert!(mem.2 > mem.3);
    assert!(!mem.1.is_empty(), "error names the op where the peak is reached");
    let msg = errors.iter().map(|e| e.to_string()).collect::<Vec<_>>().join("\n");
    assert!(msg.contains(&format!("rank(stage {}", mem.0.stage)), "{msg}");
    assert!(msg.contains("budget"), "{msg}");
}

// ---- property: every generated world is accepted -----------------------

#[test]
fn all_generators_compose_to_accepted_worlds() {
    // All five generators x stages 1..4 x dp {1,2} x tp {1,2} x
    // {partition, offload}: every applicable combination must lower to
    // a world the analyzer accepts — structurally and under the real
    // device budget.
    let cluster = ClusterSpec::reference();
    let shape = XModel::new(32).shape();
    let (d_l, n_mu, chunks) = (12usize, 4usize, 2usize);
    let mut verified = 0usize;
    for stages in 1..=4usize {
        if d_l % stages != 0 || n_mu < stages {
            continue;
        }
        for dp in [1usize, 2] {
            for tp in [1usize, 2] {
                for (partition, offload) in
                    [(false, false), (true, false), (false, true), (true, true)]
                {
                    let sp = ScheduleSpec {
                        d_l,
                        n_l: stages,
                        n_mu,
                        tp,
                        partition,
                        offload,
                        data_parallel: dp > 1,
                        zero: 0,
                    };
                    let schedules: Vec<(&str, Option<Schedule>)> = vec![
                        ("standard_ga", Some(standard_ga(&sp))),
                        ("layered_ga", (stages == 1).then(|| layered_ga(&sp))),
                        ("modular_pipeline", Some(modular_pipeline(&sp))),
                        ("one_f_one_b", Some(one_f_one_b(&sp))),
                        (
                            "interleaved_1f1b",
                            interleaved_applicable(&sp, chunks)
                                .then(|| interleaved_1f1b(&sp, chunks)),
                        ),
                    ];
                    for (name, schedule) in schedules {
                        let Some(schedule) = schedule else { continue };
                        let prog = program(&schedule);
                        let topo = Topology::new(stages, dp, tp);
                        let costs = costs_for(&sp, dp);
                        let cfg = TrainConfig {
                            strategy: Strategy::Improved,
                            n_b: dp,
                            n_l: stages,
                            n_a: tp,
                            n_mu,
                            b_mu: 1.0,
                            offload,
                            partition,
                            zero: 0,
                        };
                        let memory = MemoryBreakdown::evaluate(&shape, &cfg);
                        let budget =
                            MemoryModel::new(&costs, &memory, cluster.gpu.memory_bytes, offload);
                        let tag = format!(
                            "{name} s{stages} dp{dp} tp{tp} part={partition} off={offload}"
                        );
                        match verify_program(&prog, topo, costs.wire, Some(&budget)) {
                            Ok(()) => verified += 1,
                            Err(errors) => {
                                panic!("{tag}: rejected a generated world:\n{errors:?}")
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(verified > 150, "grid unexpectedly small: {verified} worlds");
}

// ---- serving worlds: forward-only programs under the KV bound ----------

#[test]
fn serving_grid_composes_to_accepted_worlds() {
    // The inference-serving generators ride the same analyzer: every
    // stages x tp x in-flight point must compose at dp = 1 and pass
    // all whole-world checks with the KV-cache memory model standing
    // in for the activation-checkpoint budget.
    use lga_mpp::costmodel::KvCacheModel;
    use lga_mpp::runtime::DType;
    use lga_mpp::schedule::{decode_wave, prefill_pipeline};

    let cluster = ClusterSpec::reference();
    let shape = XModel::new(8).shape();
    let (prompt, decode) = (32usize, 8usize);
    let mut verified = 0usize;
    for stages in [1usize, 2, 4, 8] {
        for tp in [1usize, 2] {
            for cap in [1usize, 2, 4, 8] {
                let sp = ScheduleSpec {
                    d_l: shape.d_l,
                    n_l: stages,
                    n_mu: cap,
                    tp,
                    partition: false,
                    offload: false,
                    data_parallel: false,
                    zero: 0,
                };
                let kv =
                    KvCacheModel::new(&shape, stages, tp, DType::F32, cluster.gpu.memory_bytes);
                let topo = Topology::new(stages, 1, tp);
                for (name, schedule, tokens, context) in [
                    ("prefill", prefill_pipeline(&sp), prompt, 0usize),
                    ("decode", decode_wave(&sp), 1, prompt + decode - 1),
                ] {
                    let prog = program(&schedule);
                    let cfg = TrainConfig {
                        strategy: Strategy::Improved,
                        n_b: 1,
                        n_l: stages,
                        n_a: tp,
                        n_mu: 1,
                        b_mu: tokens as f64 / shape.d_s as f64,
                        offload: false,
                        partition: false,
                        zero: 0,
                    };
                    let costs = CostTable::new(&shape, &cfg, &cluster);
                    let budget = MemoryModel::serving(&kv, &costs, cap, context, tokens);
                    match verify_program(&prog, topo, costs.wire, Some(&budget)) {
                        Ok(()) => verified += 1,
                        Err(errors) => panic!(
                            "serving {name} s{stages} tp{tp} cap{cap}: rejected a generated \
                             world:\n{errors:?}"
                        ),
                    }
                }
            }
        }
    }
    assert_eq!(verified, 64, "the stages x tp x in-flight grid must fully verify");
}

// ---- planner parity: the static filter changes nothing -----------------

fn rank_unfiltered(
    model: &XModel,
    cluster: &ClusterSpec,
    candidates: &[Plan],
) -> Option<SimulatedPlan> {
    candidates
        .iter()
        .map(|p| simulate_plan(model, cluster, p))
        .min_by(|a, b| a.secs_per_sequence.total_cmp(&b.secs_per_sequence))
}

#[test]
fn static_filter_preserves_planner_selection() {
    // On the planner-parity configurations (cluster x strategy at X_32)
    // every candidate the search produces must pass the static verifier,
    // and the filtered ranking must select exactly the plan the
    // unfiltered ranking selects.
    let clusters = [
        (ClusterSpec::reference(), "reference"),
        (ClusterSpec::ethernet(), "ethernet"),
        (ClusterSpec::unlimited_node(), "unlimited_node"),
    ];
    let model = XModel::new(32);
    for (cluster, cname) in &clusters {
        for strategy in Strategy::ALL {
            let menu = menu_for(strategy);
            let mut cands = Vec::new();
            cands.extend(search_fastest(&model, cluster, strategy, menu));
            cands.extend(fastest_plan(&model, cluster, strategy, menu));
            if cands.is_empty() {
                continue;
            }
            let tag = format!("{cname}/{strategy:?}");
            for plan in &cands {
                if let Err(e) = statically_valid(&model, cluster, plan) {
                    panic!("{tag}: search candidate rejected by the static filter: {e}");
                }
            }
            let filtered = rank_by_simulation(&model, cluster, &cands).expect("winner");
            let unfiltered = rank_unfiltered(&model, cluster, &cands).expect("winner");
            assert_eq!(
                filtered.plan.cfg, unfiltered.plan.cfg,
                "{tag}: the static filter changed the selected plan"
            );
            assert_eq!(
                filtered.secs_per_sequence.to_bits(),
                unfiltered.secs_per_sequence.to_bits(),
                "{tag}: the static filter changed the winning time"
            );
        }
    }
}

// ---- deadlock: a cross-rank cycle no per-rank check can see ------------

#[test]
fn cross_rank_wait_cycle_reports_a_minimal_cycle() {
    // Build a world where every rank stays locally in-order executable
    // and every channel's send/recv sequences still agree, but two
    // ranks wait on each other: rotate stage 0's first RecvGrad ahead
    // of its first SendAct. Stage 0 then blocks on a gradient that
    // stage 1 can only produce after consuming the very activation
    // stage 0 is now withholding — invisible to every per-rank and
    // per-channel check, only the cross-rank wait-for graph sees it.
    let sp = spec(8, 2, 4, 1);
    let prog = program(&one_f_one_b(&sp));
    let topo = Topology::new(2, 1, 1);
    let mut world = WorldModel::compose(&prog, topo, WireBytes::default()).unwrap();
    assert!(world.verify(None).is_empty(), "unmutated world must be clean");

    let r0 = topo.index(Rank { stage: 0, dp: 0, tp: 0 });
    let send = world.find_op(r0, |op| matches!(op, Op::SendAct { .. })).unwrap();
    let recv = world.find_op(r0, |op| matches!(op, Op::RecvGrad { .. })).unwrap();
    assert!(send < recv, "1F1B sends the first activation before any grad arrives");
    // Repeated adjacent swaps = a stable rotate: the recv lands at the
    // send's position, everything in between shifts one slot later, and
    // both channels' internal send/recv orders are untouched.
    for i in ((send + 1)..=recv).rev() {
        world.swap_ops(r0, i - 1, i);
    }

    let errors = world.verify(None);
    let cycle = errors
        .iter()
        .find_map(|e| match e {
            WorldError::Deadlock { cycle } => Some(cycle),
            _ => None,
        })
        .unwrap_or_else(|| panic!("expected a deadlock, got {errors:?}"));
    assert!(cycle.len() >= 2, "a cross-rank cycle spans at least two ops: {cycle:?}");
    let joined = cycle.join(" -> ");
    assert!(
        joined.contains("rank(stage 0") && joined.contains("rank(stage 1"),
        "cycle must name both ranks: {joined}"
    );
}
