//! End-to-end serving subsystem tests: deterministic-trace latency
//! regression, the simulator identity check, and whole-world static
//! verification of every serving deployment in a small grid.

use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::{TransformerShape, XModel};
use lga_mpp::planner::{plan_slo, verify_serving, SloSpec};
use lga_mpp::serve::{run_trace, ServeCosts, Trace};
use lga_mpp::sim::Xorshift;

fn setup() -> (TransformerShape, ClusterSpec) {
    (XModel::new(8).shape(), ClusterSpec::reference())
}

/// Latency regression on a fixed deterministic trace: the numbers are
/// relational (prefill + wave identities), so the test pins behaviour
/// without hard-coding absolute seconds that drift with the cost model.
#[test]
fn deterministic_trace_latency_regression() {
    let (shape, cluster) = setup();
    // 6 requests all arriving at t=0: one admission burst of `cap`,
    // then a second burst as slots free up.
    let trace = Trace::uniform(6, 0.0, 16, 3);
    let r = run_trace(&shape, &cluster, 2, 1, 4, &trace).unwrap();
    assert_eq!(r.completed, 6);
    assert_eq!(r.cap, 4);
    assert_eq!(r.cap_bound, "max-batch");
    assert_eq!(r.peak_in_flight, 4);

    let mut costs = ServeCosts::new(&shape, &cluster, 2, 1);
    // First burst: 4 prompts prefill together, then 3 waves of 4.
    // The remaining 2 admit after the first completions evict.
    let m0 = r.per_request[0];
    let expected_ttft = costs.prefill_latency(4, 16) + costs.decode_latency(4);
    assert!(
        (m0.ttft() - expected_ttft).abs() < 1e-12,
        "first-burst TTFT {} != prefill+wave {}",
        m0.ttft(),
        expected_ttft
    );
    assert!(
        (m0.finish - (costs.prefill_latency(4, 16) + 3.0 * costs.decode_latency(4))).abs()
            < 1e-12
    );
    // The late requests are admitted strictly after the early finishes.
    let m5 = r.per_request[5];
    assert!(m5.admitted >= m0.finish - 1e-12);

    // Replay determinism: bit-identical report.
    let again = run_trace(&shape, &cluster, 2, 1, 4, &trace).unwrap();
    assert_eq!(r.makespan, again.makespan);
    assert_eq!(r.ttft_p99, again.ttft_p99);
    assert_eq!(r.token_p99, again.token_p99);
    assert_eq!(r.waves, again.waves);

    // Token conservation ties throughput to the trace exactly.
    assert!(
        (r.tokens_per_sec * r.makespan - trace.total_decode_tokens() as f64).abs() < 1e-9
    );
}

/// Simulator identity: one request on one stage at tp = 1 means no
/// transfers, no collectives, no overlap — the reported latency must
/// equal the summed per-op cost of the compiled schedule.
#[test]
fn identity_latency_equals_summed_op_cost() {
    let (shape, cluster) = setup();
    let trace = Trace::uniform(1, 0.0, 16, 4);
    let r = run_trace(&shape, &cluster, 1, 1, 1, &trace).unwrap();
    let mut costs = ServeCosts::new(&shape, &cluster, 1, 1);
    let d_l = shape.d_l as f64;
    let prefill = d_l * costs.table(16).fwd;
    let wave = d_l * costs.table(1).fwd;
    assert!((costs.prefill_latency(1, 16) - prefill).abs() < 1e-15);
    assert!((costs.decode_latency(1) - wave).abs() < 1e-15);
    let m = r.per_request[0];
    assert!((m.ttft() - (prefill + wave)).abs() < 1e-12);
    assert!((m.finish - (prefill + 4.0 * wave)).abs() < 1e-12);
}

/// The arrival stream is seed-deterministic end to end: same seed,
/// same trace, same report; different seed, different makespan.
#[test]
fn poisson_serving_is_seed_deterministic() {
    let (shape, cluster) = setup();
    let a = run_trace(&shape, &cluster, 2, 2, 4, &Trace::poisson(3, 30.0, 20, 16, 4)).unwrap();
    let b = run_trace(&shape, &cluster, 2, 2, 4, &Trace::poisson(3, 30.0, 20, 16, 4)).unwrap();
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.ttft_p50, b.ttft_p50);
    let c = run_trace(&shape, &cluster, 2, 2, 4, &Trace::poisson(4, 30.0, 20, 16, 4)).unwrap();
    assert_ne!(a.makespan, c.makespan, "a different seed must reshuffle arrivals");

    // And the shared generator itself replays.
    let mut x = Xorshift::new(3);
    let mut y = Xorshift::new(3);
    assert!((0..64).all(|_| x.next_u64() == y.next_u64()));
}

/// Saturating the batcher (all arrivals at once, rate far above one
/// request per wave) must raise tail latency over a trickle.
#[test]
fn overload_raises_tail_latency_monotonically() {
    let (shape, cluster) = setup();
    let mut costs = ServeCosts::new(&shape, &cluster, 2, 1);
    let wave = costs.decode_latency(4);
    let hot = run_trace(&shape, &cluster, 2, 1, 4, &Trace::uniform(16, wave * 0.01, 16, 8))
        .unwrap();
    let cold = run_trace(&shape, &cluster, 2, 1, 4, &Trace::uniform(16, wave * 100.0, 16, 8))
        .unwrap();
    assert!(hot.ttft_p99 > cold.ttft_p99);
    assert!(hot.ttft_p50 >= cold.ttft_p50);
    // Batching amortises: the saturated run decodes more tokens per
    // second than the one-at-a-time trickle.
    assert!(hot.tokens_per_sec > cold.tokens_per_sec);
}

/// Every serving deployment in the grid — prefill and decode programs
/// composed over all ranks at dp = 1 — passes whole-world verification
/// including the KV-aware static memory bound.
#[test]
fn serving_grid_passes_whole_world_verification() {
    let (shape, cluster) = setup();
    let mut verified = 0usize;
    for stages in [1usize, 2, 4, 8] {
        for tp in [1usize, 2] {
            for cap in [1usize, 2, 4, 8] {
                verify_serving(&shape, &cluster, stages, tp, cap, 32, 8).unwrap_or_else(|e| {
                    panic!("stages={stages} tp={tp} cap={cap}: {e}")
                });
                verified += 1;
            }
        }
    }
    assert_eq!(verified, 32);
}

/// The SLO planner end to end: a relaxed SLO yields a feasible winner
/// whose own report satisfies it, and the winner dominates every other
/// evaluated deployment on tokens/sec.
#[test]
fn slo_planner_finds_a_feasible_throughput_maximum() {
    let (shape, cluster) = setup();
    let spec = SloSpec {
        rate: 10.0,
        slo_p99_ttft: f64::INFINITY,
        n_requests: 8,
        prompt: 16,
        decode: 4,
        seed: 2,
    };
    let plan = plan_slo(&shape, &cluster, &spec).unwrap();
    assert!(plan.infeasible.is_none());
    assert!(plan.best.meets(spec.slo_p99_ttft));
    let best = plan.best.report.tokens_per_sec;
    assert!(plan.evaluated.iter().all(|c| c.report.tokens_per_sec <= best + 1e-9));
}
