//! Property-style tests for the schedule → program lowering layer:
//! every generator × spec grid must compile to a cycle-free
//! `ScheduleProgram` whose edges encode exactly one forward/backward
//! chain per (layer, micro-batch), and the policies must keep their
//! paper-level resource relationships after lowering.

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::schedule::{
    interleaved_1f1b, interleaved_applicable, layered_ga, lower, modular_pipeline, one_f_one_b,
    standard_ga, Op, Schedule, ScheduleProgram, ScheduleSpec,
};
use lga_mpp::sim::{simulate_program, CostTable};

/// The spec grid: (d_l, n_l, n_mu) shapes exercising single-stage,
/// divisible and ragged-micro-batch pipelines, with every combination of
/// partition / offload / data-parallel flags and tensor parallelism on
/// or off.
fn grid() -> Vec<ScheduleSpec> {
    let mut specs = Vec::new();
    for (d_l, n_l, n_mu) in
        [(8, 4, 8), (16, 4, 6), (16, 4, 8), (12, 3, 6), (8, 1, 4), (160, 5, 10), (16, 2, 5)]
    {
        for partition in [false, true] {
            for offload in [false, true] {
                for data_parallel in [false, true] {
                    for tp in [1, 2] {
                        specs.push(ScheduleSpec {
                            d_l,
                            n_l,
                            n_mu,
                            tp,
                            partition,
                            offload,
                            data_parallel,
                            zero: 0,
                        });
                    }
                }
            }
        }
    }
    specs
}

/// Every generator applicable to a spec, with its schedule.
fn generated(spec: &ScheduleSpec) -> Vec<Schedule> {
    let mut out = vec![standard_ga(spec)];
    if spec.n_l == 1 {
        out.push(layered_ga(spec));
    } else {
        out.push(modular_pipeline(spec));
        out.push(one_f_one_b(spec));
    }
    if interleaved_applicable(spec, 2) {
        out.push(interleaved_1f1b(spec, 2));
    }
    out
}

fn find_op(p: &ScheduleProgram, op: Op) -> Option<u32> {
    p.find(|o| *o == op)
}

#[test]
fn every_generator_times_spec_lowers_cycle_free() {
    for spec in grid() {
        for s in generated(&spec) {
            let p = lower(&s).unwrap_or_else(|e| panic!("{} {spec:?}: {e:?}", s.name));
            // And every generated schedule survives the trainer's
            // stricter synchronous in-order executability check.
            p.check_inorder_executable()
                .unwrap_or_else(|e| panic!("{} {spec:?} in-order: {e:?}", s.name));
        }
    }
}

#[test]
fn exactly_one_fwd_bwd_edge_chain_per_layer_and_microbatch() {
    for spec in grid() {
        for s in generated(&spec) {
            let p = lower(&s).unwrap();
            let is_restore = |id: u32| matches!(p.ops[id as usize].op, Op::RestoreParams { .. });
            for l in 0..spec.d_l {
                for mb in 0..spec.n_mu {
                    // Exactly one Fwd and one Bwd node per pair.
                    assert_eq!(p.count(|o| *o == Op::Fwd { layer: l, mb }), 1, "{}", s.name);
                    assert_eq!(p.count(|o| *o == Op::Bwd { layer: l, mb }), 1, "{}", s.name);
                    let fwd = find_op(&p, Op::Fwd { layer: l, mb }).unwrap();
                    let bwd = find_op(&p, Op::Bwd { layer: l, mb }).unwrap();

                    // Forward chain: layer 0 has no data dependency; every
                    // other layer depends on exactly one activation
                    // producer (the previous layer's Fwd — or its tp
                    // all-reduce, which supersedes it as producer of the
                    // reduced tensor — or the RecvAct re-homing it), plus
                    // possibly a parameter restore.
                    let fwd_data: Vec<u32> = p
                        .preds_of(fwd)
                        .iter()
                        .copied()
                        .filter(|&x| !is_restore(x))
                        .collect();
                    if l == 0 {
                        assert!(fwd_data.is_empty(), "{} F{l}.{mb}", s.name);
                    } else {
                        assert_eq!(fwd_data.len(), 1, "{} F{l}.{mb}", s.name);
                        let producer = p.ops[fwd_data[0] as usize].op;
                        let want_local = if spec.tp > 1 {
                            Op::TensorAllReduce { layer: l - 1, mb, bwd: false }
                        } else {
                            Op::Fwd { layer: l - 1, mb }
                        };
                        assert!(
                            producer == want_local || producer == Op::RecvAct { layer: l, mb },
                            "{} F{l}.{mb} <- {producer}",
                            s.name
                        );
                    }

                    // Backward chain: always the checkpoint (its own Fwd),
                    // plus — below the last layer — exactly one gradient
                    // producer (the next layer's Bwd, or the RecvGrad).
                    let bwd_data: Vec<u32> = p
                        .preds_of(bwd)
                        .iter()
                        .copied()
                        .filter(|&x| !is_restore(x))
                        .collect();
                    assert!(bwd_data.contains(&fwd), "{} B{l}.{mb} missing checkpoint", s.name);
                    if l + 1 == spec.d_l {
                        assert_eq!(bwd_data.len(), 1, "{} B{l}.{mb}", s.name);
                    } else {
                        assert_eq!(bwd_data.len(), 2, "{} B{l}.{mb}", s.name);
                        let grad = bwd_data.iter().find(|&&x| x != fwd).unwrap();
                        let producer = p.ops[*grad as usize].op;
                        let want_local = if spec.tp > 1 {
                            Op::TensorAllReduce { layer: l + 1, mb, bwd: true }
                        } else {
                            Op::Bwd { layer: l + 1, mb }
                        };
                        assert!(
                            producer == want_local || producer == Op::RecvGrad { layer: l, mb },
                            "{} B{l}.{mb} <- {producer}",
                            s.name
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn modular_restores_strictly_fewer_than_standard_under_partition() {
    for spec in grid() {
        // The restore economy holds on the partition path and the offload
        // path alike (Figure 2 / §8.2).
        if !spec.restores() || spec.n_l == 1 {
            continue;
        }
        let modular = lower(&modular_pipeline(&spec)).unwrap();
        let standard = lower(&standard_ga(&spec)).unwrap();
        let restores = |p: &ScheduleProgram| p.count(|o| matches!(o, Op::RestoreParams { .. }));
        assert!(
            restores(&modular) < restores(&standard),
            "{spec:?}: modular {} vs standard {}",
            restores(&modular),
            restores(&standard)
        );
        // The exact factor-n_mu economy of Figure 2.
        assert_eq!(restores(&modular) * spec.n_mu, restores(&standard));
    }
}

#[test]
fn lowered_programs_simulate_without_deadlock() {
    let cluster = ClusterSpec::reference();
    for spec in grid() {
        let cfg = TrainConfig {
            strategy: if spec.partition { Strategy::Improved } else { Strategy::Baseline },
            n_b: if spec.data_parallel { 4 } else { 1 },
            n_l: spec.n_l,
            n_a: spec.tp,
            n_mu: spec.n_mu,
            b_mu: 1.0,
            offload: spec.offload,
            partition: spec.partition,
            zero: 0,
        };
        let costs = CostTable::new(&XModel::new(16).shape(), &cfg, &cluster);
        for s in generated(&spec) {
            let p = lower(&s).unwrap();
            let r = simulate_program(&p, &costs);
            assert!(r.makespan.is_finite() && r.makespan > 0.0, "{} {spec:?}", s.name);
            assert!(r.compute_efficiency() > 0.0 && r.compute_efficiency() <= 1.0 + 1e-9);
        }
    }
}

#[test]
fn program_edges_are_within_arena_and_acyclicity_witness_exists() {
    // Structural sanity on a large program: every edge endpoint is a
    // valid arena id, and a topological order exists (spot-checked by
    // following each pred's id being executable before its consumer in
    // *some* order — lowering already ran Kahn; here we just re-verify
    // the CSR symmetry).
    let spec = ScheduleSpec {
        d_l: 160,
        n_l: 5,
        n_mu: 10,
        tp: 1,
        partition: true,
        offload: true,
        data_parallel: true,
        zero: 0,
    };
    let p = lower(&modular_pipeline(&spec)).unwrap();
    let n = p.len() as u32;
    let mut pred_edge_count = 0usize;
    for id in 0..n {
        for &x in p.preds_of(id) {
            assert!(x < n);
            assert!(p.succs_of(x).contains(&id));
            pred_edge_count += 1;
        }
    }
    assert_eq!(pred_edge_count, p.n_edges());
}
