//! §8.2 executable checkpoint path, end to end.
//!
//! Two halves:
//! * schedule-level: the Figure-2 restore/store op accounting on the
//!   offload path (runs everywhere, no artifacts needed);
//! * runtime-level: the crash/resume scenario — train with `--offload`
//!   streaming to a durable `FileStore`, stop ("crash"), then resume
//!   from the streamed checkpoint on a *different* data-parallel degree
//!   and land on the same loss trajectory as an uninterrupted run.
//!   Needs the PJRT artifacts (`make artifacts`); skips gracefully
//!   without them, and CI runs it in the release-mode parity step.

use std::path::PathBuf;

use lga_mpp::offload::{FileStore, StateStore};
use lga_mpp::optim::LrSchedule;
use lga_mpp::schedule::{
    layered_ga, lower, modular_pipeline, standard_ga, Op, ScheduleProgram, ScheduleSpec,
};
use lga_mpp::trainer::{train, Policy, TrainerConfig};

fn restores(p: &ScheduleProgram) -> usize {
    p.count(|o| matches!(o, Op::RestoreParams { .. }))
}

fn stores(p: &ScheduleProgram) -> usize {
    p.count(|o| matches!(o, Op::OffloadStore { .. }))
}

#[test]
fn figure2_restore_store_ratio_on_the_offload_path() {
    // The ν accounting behind §8.2: per batch, standard gradient
    // accumulation restores every layer once per micro-batch per pass
    // (2·d_l·n_μ restores), while the modular pipeline / LGA restore once
    // per layer per pass (2·d_l) — the factor-n_μ economy of Figure 2,
    // now on the offload path. Stores are once per layer either way.
    let (d_l, n_l, n_mu) = (16usize, 4usize, 8usize);
    let spec = ScheduleSpec {
        d_l,
        n_l,
        n_mu,
        tp: 1,
        partition: false,
        offload: true,
        data_parallel: true,
        zero: 0,
    };
    let std_p = lower(&standard_ga(&spec)).expect("standard lowers");
    let mod_p = lower(&modular_pipeline(&spec)).expect("modular lowers");
    assert_eq!(restores(&std_p), 2 * d_l * n_mu);
    assert_eq!(restores(&mod_p), 2 * d_l);
    assert_eq!(restores(&std_p), n_mu * restores(&mod_p), "Figure 2 ratio");
    assert_eq!(stores(&std_p), d_l);
    assert_eq!(stores(&mod_p), d_l);

    // Single-stage LGA keeps the same economy.
    let single = ScheduleSpec { n_l: 1, ..spec };
    let lga_p = lower(&layered_ga(&single)).expect("lga lowers");
    assert_eq!(restores(&lga_p), 2 * d_l);
    assert_eq!(stores(&lga_p), d_l);
}

// ---------------------------------------------------------------------------
// crash / elastic-resume integration (needs PJRT artifacts)
// ---------------------------------------------------------------------------

fn have_artifacts() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny/manifest.json").exists()
}

fn temp_store(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lga_resume_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn config(n_b: usize, n_mu: usize, steps: usize, store: PathBuf) -> TrainerConfig {
    let mut c = TrainerConfig::quick("tiny");
    c.steps = steps;
    c.n_b = n_b;
    c.n_mu = n_mu;
    c.policy = Policy::Improved;
    // Partition when data-parallel: the crashed run then writes *sharded*
    // records, which the resumed run must re-slice.
    c.partition = n_b > 1;
    c.offload = true;
    c.store_dir = Some(store);
    c.lr = LrSchedule::constant(3e-3);
    c
}

#[test]
fn crash_and_elastic_resume_match_an_uninterrupted_run() {
    if !have_artifacts() {
        return;
    }
    let steps = 8usize;
    let kill_at = 4usize;

    // Uninterrupted reference: 2-way data parallel, 2 micro-batches,
    // partitioned state, streaming real-time checkpoints throughout.
    let dir_ref = temp_store("reference");
    let ra = train(&config(2, 2, steps, dir_ref.clone())).expect("reference run");
    assert_eq!(ra.start_step, 0);
    assert_eq!(ra.losses.len(), steps);

    // The "crashed" run: identical config, killed after `kill_at` steps —
    // nothing survives except what was already streamed per step.
    let dir = temp_store("crashed");
    let rb = train(&config(2, 2, kill_at, dir.clone())).expect("crashed run");
    assert!(rb.checkpoint_records > 0 && rb.checkpoint_bytes_written > 0);
    // The streamed state is byte-for-byte readable as a store; retention
    // keeps the last two steps (in-flight + last complete), older ones
    // are pruned as training advances.
    let store = FileStore::new(&dir).expect("reopen store");
    let retained = store.steps().expect("steps");
    assert_eq!(retained, vec![kill_at as u64 - 2, kill_at as u64 - 1]);

    // Resuming with a *different global batch* must be refused — it
    // would silently change the trajectory the checkpoint promises.
    let mut bad = config(1, 2, steps, dir.clone());
    bad.resume = true;
    let err = train(&bad).expect_err("global-batch mismatch must fail");
    assert!(format!("{err:#}").contains("global batch"), "{err:#}");

    // Elastic resume on a *different* cluster: 1-way data parallel with 4
    // micro-batches (same global batch), so every sharded record has to
    // be re-sliced through ShardMap on load.
    let mut cfg = config(1, 4, steps, dir.clone());
    cfg.resume = true;
    let rc = train(&cfg).expect("resumed run");
    assert_eq!(rc.start_step, kill_at, "resume picks up right after the last complete step");
    assert_eq!(rc.losses.len(), steps - kill_at);

    // Acceptance: the resumed trajectory matches the uninterrupted one to
    // fp tolerance (micro-batches are keyed globally, so the global batch
    // per step is identical; only fp reduction order differs).
    for (i, (x, y)) in ra.losses[kill_at..].iter().zip(&rc.losses).enumerate() {
        assert!(
            (x - y).abs() < 3e-3,
            "step {}: uninterrupted {x} vs resumed {y}",
            kill_at + i
        );
    }

    // A supervisor restarting the finished run exits cleanly with
    // nothing left to train (not an error loop).
    let done = train(&cfg).expect("already-complete resume");
    assert_eq!(done.start_step, steps);
    assert!(done.losses.is_empty());

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir_ref);
}

#[test]
fn resume_with_empty_store_is_a_cold_start() {
    if !have_artifacts() {
        return;
    }
    let dir = temp_store("cold");
    let mut cfg = config(1, 2, 2, dir.clone());
    cfg.resume = true; // nothing to resume from yet
    let r = train(&cfg).expect("cold start");
    assert_eq!(r.start_step, 0);
    assert_eq!(r.losses.len(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}
