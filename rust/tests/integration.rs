//! Cross-module integration tests: planner ↔ simulator agreement, schedule
//! → simulator → metrics pipelines, and end-to-end consistency checks that
//! span more than one subsystem.

use lga_mpp::costmodel::{
    bubble_fraction, estimate, ParallelismMenu, Strategy, TrainConfig,
};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::planner::{fastest_plan, search_fastest};
use lga_mpp::schedule::{modular_pipeline, standard_ga, validate, ScheduleSpec};
use lga_mpp::sim::{simulate, CostTable};

/// The closed-form bubble (cost model) and the measured bubble (simulator)
/// agree for both pipeline flavours across a grid of shapes.
#[test]
fn simulator_validates_costmodel_bubble() {
    let shape = XModel::new(32).shape(); // d_l = 32
    for (n_l, n_mu) in [(2usize, 4usize), (4, 8), (4, 16), (8, 8), (8, 32)] {
        for improved in [false, true] {
            let cfg = TrainConfig {
                strategy: if improved { Strategy::Improved } else { Strategy::Baseline },
                n_b: 1,
                n_l,
                n_a: 1,
                n_mu,
                b_mu: 1.0,
                offload: false,
                partition: false,
                zero: 0,
            };
            let spec = ScheduleSpec {
                d_l: shape.d_l,
                n_l,
                n_mu,
                tp: 1,
                partition: false,
                offload: false,
                data_parallel: false,
                zero: 0,
            };
            let sched = if improved { modular_pipeline(&spec) } else { standard_ga(&spec) };
            validate(&sched).unwrap();
            // Compute-only cost table isolates the bubble (the closed form
            // ignores transfer and optimizer time).
            let mut costs = CostTable::new(&shape, &cfg, &ClusterSpec::reference());
            costs.send_act = 0.0;
            costs.send_grad = 0.0;
            costs.reduce_grad = 0.0;
            costs.restore_params = 0.0;
            costs.optim_step = 0.0;
            let measured = simulate(&sched, &costs).bubble_fraction();
            let predicted = bubble_fraction(&shape, &cfg);
            assert!(
                (measured - predicted).abs() < 1e-9,
                "n_l={n_l} n_mu={n_mu} improved={improved}: sim {measured:.6} vs model {predicted:.6}"
            );
        }
    }
}

/// Planner output simulates at (or above) its predicted efficiency when
/// run through the discrete-event engine with the same assumptions.
#[test]
fn planned_improved_config_simulates_efficiently() {
    let model = XModel::new(64);
    let cluster = ClusterSpec::reference();
    let plan = fastest_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::DATA_PIPE)
        .expect("plan");
    let mut cfg = plan.cfg;
    // The planner optimises over continuous structures; the executable
    // schedule needs n_l | d_l. Snap to the nearest divisor.
    let d_l = model.shape().d_l;
    while d_l % cfg.n_l != 0 {
        cfg.n_l -= 1;
    }
    cfg.n_mu = cfg.n_mu.max(cfg.n_l);
    let spec = ScheduleSpec {
        d_l,
        n_l: cfg.n_l,
        n_mu: cfg.n_mu,
        tp: 1,
        partition: cfg.partition,
        offload: cfg.offload,
        data_parallel: cfg.n_b > 1,
        zero: 0,
    };
    let sched = modular_pipeline(&spec);
    let costs = CostTable::new(&model.shape(), &cfg, &cluster);
    let r = simulate(&sched, &costs);
    // The simulator adds costs the closed form ignores (optimizer step,
    // exposed sends), so allow a modest gap.
    assert!(
        r.compute_efficiency() > plan.speed.efficiency * 0.8,
        "sim eff {:.3} vs planned {:.3}",
        r.compute_efficiency(),
        plan.speed.efficiency
    );
}

/// The improved strategy never loses to the baseline by more than noise at
/// BERT scale and above, on every cluster variant — the paper's global
/// claim assembled from planner + cost model.
#[test]
fn improved_dominates_across_clusters_and_scales() {
    for (ci, cluster) in [
        ClusterSpec::reference(),
        ClusterSpec::ethernet(),
        ClusterSpec::unlimited_node(),
    ]
    .into_iter()
    .enumerate()
    {
        for x in [32usize, 64, 108, 160] {
            if ci == 1 && x < 64 {
                // Ethernet at sub-GPT2 scale: both strategies sit at
                // ~0.1 efficiency (fully comm-bound) and the winner is
                // inside the cost model's noise — see EXPERIMENTS.md
                // deviations.
                continue;
            }
            let m = XModel::new(x);
            let b = search_fastest(&m, &cluster, Strategy::Baseline, ParallelismMenu::THREE_D);
            let i = search_fastest(&m, &cluster, Strategy::Improved, ParallelismMenu::THREE_D);
            let (b, i) = (b.unwrap(), i.unwrap());
            assert!(
                i.speed.training_secs <= b.speed.training_secs * 1.02,
                "x={x}: improved {:.1}d vs baseline {:.1}d",
                i.speed.training_days(),
                b.speed.training_days()
            );
        }
    }
}

/// Memory accounting consistency: the simulator's peak checkpoint memory
/// for a GPipe schedule matches the cost model's checkpoint formula.
#[test]
fn simulator_memory_matches_costmodel_checkpoints() {
    let model = XModel::new(32);
    let shape = model.shape();
    let (n_l, n_mu, b_mu) = (4usize, 8usize, 2.0f64);
    let cfg = TrainConfig {
        strategy: Strategy::Baseline,
        n_b: 1,
        n_l,
        n_a: 1,
        n_mu,
        b_mu,
        offload: false,
        partition: false,
        zero: 0,
    };
    let spec = ScheduleSpec {
        d_l: shape.d_l,
        n_l,
        n_mu,
        tp: 1,
        partition: false,
        offload: false,
        data_parallel: false,
        zero: 0,
    };
    let costs = CostTable::new(&shape, &cfg, &ClusterSpec::reference());
    let r = simulate(&standard_ga(&spec), &costs);
    // GPipe: every stage holds all n_mu micro-batches' checkpoints for its
    // d_l/n_l layers at the fwd/bwd boundary.
    let expect = costs.checkpoint_bytes * (n_mu * shape.d_l / n_l) as f64;
    let peak = r.peak_memory.iter().cloned().fold(0.0, f64::max) - costs.live_activation_bytes;
    assert!(
        (peak / expect - 1.0).abs() < 0.01,
        "peak {peak:.3e} vs expected {expect:.3e}"
    );
}

/// Cost-model estimate is monotone: adding tensor-parallel overhead can
/// only reduce efficiency; more micro-batches can only shrink the bubble.
#[test]
fn estimate_monotonicity_properties() {
    let model = XModel::x160();
    let cluster = ClusterSpec::reference();
    let base = TrainConfig {
        strategy: Strategy::Improved,
        n_b: 100,
        n_l: 5,
        n_a: 1,
        n_mu: 5,
        b_mu: 1.0,
        offload: false,
        partition: true,
        zero: 0,
    };
    let e1 = estimate(&model, &base, &cluster);
    let mut tp = base;
    tp.n_a = 16;
    let e2 = estimate(&model, &tp, &cluster);
    assert!(e2.efficiency < e1.efficiency);
    let mut more_mu = base;
    more_mu.n_mu = 20;
    let e3 = estimate(&model, &more_mu, &cluster);
    assert!(e3.overheads.bubble < e1.overheads.bubble);
}

/// Property sweep (hand-rolled, deterministic PRNG): every generated
/// schedule across random shapes validates and simulates without
/// deadlock, and modular never has a larger bubble than contiguous.
#[test]
fn property_random_schedules_validate_and_simulate() {
    use lga_mpp::data::Rng;
    let mut rng = Rng::new(0xfeed);
    let shape = XModel::new(16).shape(); // d_l = 16
    for _ in 0..25 {
        let n_l = [1usize, 2, 4, 8, 16][rng.below(5)];
        let n_mu = n_l + rng.below(12);
        let partition = rng.below(2) == 1;
        let spec = ScheduleSpec {
            d_l: 16,
            n_l,
            n_mu,
            tp: 1,
            partition,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
        let cfg = TrainConfig {
            strategy: Strategy::Improved,
            n_b: 4,
            n_l,
            n_a: 1,
            n_mu,
            b_mu: 1.0,
            offload: false,
            partition,
            zero: 0,
        };
        let costs = CostTable::new(&shape, &cfg, &ClusterSpec::reference());
        let schedules = if n_l == 1 {
            vec![standard_ga(&spec), lga_mpp::schedule::layered_ga(&spec)]
        } else {
            vec![
                standard_ga(&spec),
                modular_pipeline(&spec),
                lga_mpp::schedule::one_f_one_b(&spec),
            ]
        };
        let mut bubbles = Vec::new();
        for s in schedules {
            validate(&s).unwrap_or_else(|e| panic!("{} {spec:?}: {e:?}", s.name));
            let r = simulate(&s, &costs);
            assert!(r.makespan.is_finite() && r.makespan > 0.0);
            bubbles.push((s.name.clone(), r.bubble_fraction()));
        }
        if n_l > 1 {
            let get = |n: &str| bubbles.iter().find(|(b, _)| b.contains(n)).unwrap().1;
            assert!(
                get("modular") <= get("standard") + 1e-9,
                "{spec:?}: {bubbles:?}"
            );
        }
    }
}
