//! Perf bench: failure-recovery accounting — expected lost work vs
//! durable-checkpoint interval, on a lowered offloaded modular-pipeline
//! program with a seeded failure draw. This is the quantitative side of
//! the Figure 2 restore-ratio argument: streamed (interval-1)
//! checkpoints bound the rollback to the in-flight step, while classic
//! intervals lose up to a whole interval per failure. Run via
//! `cargo bench --bench chaos_recovery`; writes
//! `BENCH_chaos_recovery.json`.

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::report::BenchJson;
use lga_mpp::schedule::{lower, modular_pipeline, ScheduleSpec};
use lga_mpp::sim::{recovery_costs, simulate_with_failures, CostTable, FailureEvent};

fn main() {
    let mut json = BenchJson::new("chaos_recovery");

    let spec = ScheduleSpec {
        d_l: 32,
        n_l: 8,
        n_mu: 8,
        tp: 1,
        partition: true,
        offload: true,
        data_parallel: true,
        zero: 0,
    };
    let cfg = TrainConfig {
        strategy: Strategy::Improved,
        n_b: 4,
        n_l: 8,
        n_a: 1,
        n_mu: 8,
        b_mu: 1.0,
        offload: true,
        partition: true,
        zero: 0,
    };
    let costs = CostTable::new(&XModel::new(64).shape(), &cfg, &ClusterSpec::reference());
    let program = lower(&modular_pipeline(&spec)).expect("offloaded modular pipeline lowers");
    let (step_secs, restore_secs) = recovery_costs(&program, &costs);
    println!("offloaded modular pipeline (d_l=32, n_l=8, n_mu=8):");
    println!("{:>24} {:>12.3} ms", "step", step_secs * 1e3);
    println!("{:>24} {:>12.3} ms", "restore per failure", restore_secs * 1e3);
    json.push("step_secs", step_secs);
    json.push("restore_secs", restore_secs);

    // A seeded failure draw (golden-ratio phase spread, mean gap ~40
    // steps) replayed against every checkpoint interval, so the only
    // variable across rows is how much work each failure rolls back.
    let steps = 4096usize;
    let mean_gap = 40.0 * step_secs;
    let mut t = 0.0f64;
    let mut events = Vec::new();
    let mut k = 0usize;
    while t < 0.9 * steps as f64 * step_secs {
        let phase = (k as f64 * 0.618_033_988_749_894_9).fract();
        t += mean_gap * (0.5 + phase);
        events.push(FailureEvent { at_secs: t, stage: 0 });
        k += 1;
    }
    println!("{} seeded failures over {} steps (mean gap ~40 steps):", events.len(), steps);
    json.push("failures", events.len() as f64);
    json.push("steps", steps as f64);

    for interval in [1usize, 2, 4, 8, 16, 32] {
        let acc = simulate_with_failures(&program, &costs, steps, interval, &events);
        let rolled: usize = acc.failures.iter().map(|f| f.rolled_back_steps).sum();
        println!(
            "{:>18} {:>2} {:>10.4}% lost | {:>6} steps rolled back | wall {:>10.1}s",
            "ckpt interval",
            interval,
            acc.lost_fraction * 100.0,
            rolled,
            acc.wall_secs
        );
        json.push(&format!("lost_fraction_interval_{interval}"), acc.lost_fraction);
        json.push(&format!("rolled_back_steps_interval_{interval}"), rolled as f64);
    }
    json.finish();
}
