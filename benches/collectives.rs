//! Perf bench: ring collective throughput over the in-memory channels —
//! the trainer's DP-reduction substrate. Run via `cargo bench --bench collectives`.

use std::thread;
use std::time::Instant;

use lga_mpp::collective::ring_group;
use lga_mpp::report::BenchJson;

fn bench_all_reduce(n: usize, len: usize, iters: usize) -> f64 {
    let comms = ring_group(n);
    let handles: Vec<_> = comms
        .into_iter()
        .map(|mut c| {
            thread::spawn(move || {
                let mut d = vec![1.0f32; len];
                let t0 = Instant::now();
                for _ in 0..iters {
                    c.all_reduce(&mut d);
                }
                t0.elapsed().as_secs_f64() / iters as f64
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).fold(0.0, f64::max)
}

fn main() {
    let mut json = BenchJson::new("collectives");
    println!("{:>6} {:>12} {:>12} {:>12}", "ranks", "elements", "ms/op", "GB/s eff");
    for n in [2usize, 4, 8] {
        for len in [1 << 14, 1 << 18, 1 << 22] {
            let iters = if len >= 1 << 22 { 5 } else { 20 };
            let secs = bench_all_reduce(n, len, iters);
            // Effective algorithm bandwidth: 2·(n−1)/n·len·4 bytes moved
            // per rank per op.
            let bytes = 2.0 * (n as f64 - 1.0) / n as f64 * len as f64 * 4.0;
            println!(
                "{:>6} {:>12} {:>12.3} {:>12.2}",
                n,
                len,
                secs * 1e3,
                bytes / secs / 1e9
            );
            json.push(&format!("gbps.ranks{n}.len{len}"), bytes / secs / 1e9);
        }
    }
    json.finish();
}
