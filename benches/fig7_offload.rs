//! Bench + regeneration for Figures 6 and 7: the no-memory-wall ratio and
//! the offload-intensity analysis. Run via `cargo bench --bench fig7_offload`.

use std::time::Instant;

use lga_mpp::hardware::{ClusterSpec, LinkKind};
use lga_mpp::report::{ascii_plot, figure6, figure7, BenchJson, Series};

fn main() {
    let mut json = BenchJson::new("fig7_offload");
    let cluster = ClusterSpec::reference();

    let t0 = Instant::now();
    let f6 = figure6(&cluster, 640);
    json.push("figure6_sweep_secs", t0.elapsed().as_secs_f64());
    println!("== Figure 6: memory/compute ratio for one-month training ({:.2}s) ==", t0.elapsed().as_secs_f64());
    println!("{}", ascii_plot(&[("bytes per flop/s", &f6)], 72, 16, "memory/compute"));
    // No memory wall: the ratio falls with scale.
    let first = f6[2].1;
    let last = f6.last().unwrap().1;
    println!("ratio: {first:.3e} (small) -> {last:.3e} (large); falls: {}", last < first);
    assert!(last < first, "memory wall detected?!");

    let t0 = Instant::now();
    let pts = figure7(&cluster, 640);
    json.push("figure7_sweep_secs", t0.elapsed().as_secs_f64());
    println!("\n== Figure 7: offload arithmetic intensity ({:.2}s) ==", t0.elapsed().as_secs_f64());
    let state: Series = pts.iter().map(|&(x, s, _)| (x, s)).collect();
    let ckpt: Series = pts.iter().map(|&(x, _, c)| (x, c)).collect();
    println!("{}", ascii_plot(&[("state", &state), ("checkpoint", &ckpt)], 72, 16, "flops/B"));
    let gpu = cluster.gpu;
    for (tier_name, thr) in [
        ("CPU", LinkKind::CpuGpu.intensity_threshold(&gpu)),
        ("NVMe", LinkKind::DiskNvme.intensity_threshold(&gpu)),
        ("Ethernet", LinkKind::Ethernet.intensity_threshold(&gpu)),
        ("HDD", LinkKind::DiskHdd.intensity_threshold(&gpu)),
    ] {
        let first_free = pts.iter().find(|&&(_, s, _)| s >= thr).map(|&(x, _, _)| x);
        println!(
            "  state offload to {tier_name:<9} free from X_{}",
            first_free.map(|x| x.to_string()).unwrap_or_else(|| "never".into())
        );
    }
    // §8.2: at the trillion scale (x = 160) even HDDs keep up.
    let hdd = LinkKind::DiskHdd.intensity_threshold(&gpu);
    let x160 = pts.iter().find(|&&(x, _, _)| x >= 160).unwrap();
    assert!(x160.1 > hdd);
    json.push("x160_state_intensity_flops_per_byte", x160.1);
    json.finish();
}
