//! Perf bench: `Schedule` → `ScheduleProgram` lowering throughput, and
//! the cost of re-simulating an already-lowered program (the planner's
//! simulate-in-the-loop pattern — lower once, price many cost tables).
//!
//! The acceptance config for the dependency-graph refactor is
//! d_l=128, n_l=32, n_mu=128: the simulator must be no slower than the
//! token-matching engine it replaced (seed target: ≥ 1 M ops/s; the
//! pre-refactor engine rescanned dependencies per event, the rewritten
//! one walks precomputed edges).
//!
//! Run via `cargo bench --bench schedule_program`.

use std::time::Instant;

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::report::BenchJson;
use lga_mpp::schedule::{
    interleaved_1f1b, interleaved_applicable, lower, modular_pipeline, one_f_one_b, standard_ga,
    Schedule, ScheduleSpec,
};
use lga_mpp::sim::{simulate_program, CostTable};

fn best_of<F: FnMut() -> f64>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn bench_one(name: &str, sched: &Schedule, costs: &CostTable) -> f64 {
    let n_ops = sched.len();
    let lower_t = best_of(7, || lower(sched).unwrap().n_edges() as f64);
    let program = lower(sched).unwrap();
    let exec_t = best_of(7, || simulate_program(&program, costs).makespan);
    let lower_mops = n_ops as f64 / lower_t / 1e6;
    let exec_mops = n_ops as f64 / exec_t / 1e6;
    println!(
        "{:<34} {:>8} ops {:>9} edges | lower {:>8.3} ms ({:>7.2} Mops/s) | sim {:>8.3} ms ({:>7.2} Mops/s)",
        name,
        n_ops,
        program.n_edges(),
        lower_t * 1e3,
        lower_mops,
        exec_t * 1e3,
        exec_mops
    );
    exec_mops
}

fn main() {
    let mut json = BenchJson::new("schedule_program");
    let cluster = ClusterSpec::reference();
    let mk_costs = |n_l: usize, n_mu: usize, part: bool| {
        let cfg = TrainConfig {
            strategy: if part { Strategy::Improved } else { Strategy::Baseline },
            n_b: 8,
            n_l,
            n_a: 1,
            n_mu,
            b_mu: 1.0,
            offload: false,
            partition: part,
            zero: 0,
        };
        CostTable::new(&XModel::new(32).shape(), &cfg, &cluster)
    };

    println!("== lowering + precompiled-simulation throughput ==\n");
    for (d_l, n_l, n_mu, part) in
        [(16usize, 4usize, 8usize, false), (64, 8, 16, true), (160, 5, 32, true)]
    {
        let spec = ScheduleSpec {
            d_l,
            n_l,
            n_mu,
            tp: 1,
            partition: part,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
        let costs = mk_costs(n_l, n_mu, part);
        bench_one(&format!("modular {d_l}L/{n_l}S/{n_mu}mb"), &modular_pipeline(&spec), &costs);
        bench_one(&format!("gpipe   {d_l}L/{n_l}S/{n_mu}mb"), &standard_ga(&spec), &costs);
        bench_one(&format!("1f1b    {d_l}L/{n_l}S/{n_mu}mb"), &one_f_one_b(&spec), &costs);
        if interleaved_applicable(&spec, 2) {
            bench_one(
                &format!("inter2  {d_l}L/{n_l}S/{n_mu}mb"),
                &interleaved_1f1b(&spec, 2),
                &costs,
            );
        }
    }

    // Acceptance config: the planner's simulate-in-the-loop scale.
    println!("\n== acceptance: d_l=128, n_l=32, n_mu=128 ==\n");
    let spec =
        ScheduleSpec {
            d_l: 128,
            n_l: 32,
            n_mu: 128,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
    let costs = mk_costs(32, 128, false);
    let mut worst = f64::MAX;
    worst = worst.min(bench_one("modular 128L/32S/128mb", &modular_pipeline(&spec), &costs));
    worst = worst.min(bench_one("gpipe   128L/32S/128mb", &standard_ga(&spec), &costs));
    worst = worst.min(bench_one("1f1b    128L/32S/128mb", &one_f_one_b(&spec), &costs));
    worst = worst.min(bench_one("inter2  128L/32S/128mb", &interleaved_1f1b(&spec, 2), &costs));
    println!(
        "\nworst-case precompiled simulator throughput: {worst:.2} M ops/s (seed engine target: 1.0)"
    );
    json.push("acceptance_worst_exec_mops_per_sec", worst);
    json.finish();
}
