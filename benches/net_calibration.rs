//! Perf bench: the socket transport's measured wire — round-trip
//! latency, sustained framed bandwidth, and the 2-rank ring all-reduce
//! rate — plus what those measurements do to the cost model's pricing.
//! Run via `cargo bench --bench net_calibration`; writes
//! `BENCH_net_calibration.json`, the same calibration document `repro
//! netbench` produces (consumable anywhere via `--calibration FILE`).

use lga_mpp::collective::netbench;
use lga_mpp::hardware::{ClusterSpec, NetCalibration, GIB};
use lga_mpp::report::BenchJson;

fn main() {
    let mut json = BenchJson::new("net_calibration");
    let payload_elems = (4usize << 20) / 4; // 4 MiB frames
    let probe = match netbench(payload_elems, 512, 64) {
        Ok(p) => p,
        Err(e) => {
            println!("netbench failed (no loopback?): {e}");
            json.finish();
            return;
        }
    };
    println!("loopback socket transport, 4 MiB frames:");
    println!("{:>24} {:>12.1} us", "rtt (median)", probe.rtt_secs * 1e6);
    println!(
        "{:>24} {:>12.2} GiB/s",
        "stream bandwidth",
        probe.bandwidth_bytes_per_s / GIB
    );
    println!(
        "{:>24} {:>12.2} GiB/s",
        "ring all-reduce/rank",
        probe.ring_allreduce_bytes_per_s / GIB
    );

    // What calibration does to the planner's arithmetic-intensity
    // thresholds: quoted spec sheet vs the wire we just measured.
    let quoted = ClusterSpec::reference();
    let calibrated = quoted.with_calibration(NetCalibration {
        bandwidth_bytes_per_s: probe.bandwidth_bytes_per_s,
        rtt_secs: probe.rtt_secs,
    });
    println!(
        "{:>24} {:>12.3e} flops/B quoted -> {:.3e} calibrated",
        "inter-node threshold",
        quoted.inter_node_threshold(),
        calibrated.inter_node_threshold()
    );

    json.push("rtt_secs", probe.rtt_secs);
    json.push("bandwidth_bytes_per_s", probe.bandwidth_bytes_per_s);
    json.push("ring_allreduce_bytes_per_s", probe.ring_allreduce_bytes_per_s);
    json.push("payload_bytes", probe.payload_bytes as f64);
    json.push("threshold_quoted", quoted.inter_node_threshold());
    json.push("threshold_calibrated", calibrated.inter_node_threshold());
    json.finish();
}
