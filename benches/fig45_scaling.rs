//! Bench + regeneration for Figures 4, 5 and 8: the scaling sweeps
//! (training time and memory vs model size) on the three clusters.
//! Run via `cargo bench --bench fig45_scaling`.

use std::time::Instant;

use lga_mpp::costmodel::Strategy;
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::report::{ascii_plot, scaling_figure, BenchJson, Series};

fn main() {
    let mut json = BenchJson::new("fig45_scaling");
    let max_x = 320;
    for (cluster, name, tag) in [
        (ClusterSpec::reference(), "Figure 4 (node <= 16, InfiniBand)", "fig4"),
        (ClusterSpec::unlimited_node(), "Figure 5 (no node-size limit)", "fig5"),
        (ClusterSpec::ethernet(), "Figure 8 (25 Gb/s Ethernet)", "fig8"),
    ] {
        let t0 = Instant::now();
        let fig = scaling_figure(&cluster, name, max_x);
        let dt = t0.elapsed().as_secs_f64();
        json.push(&format!("sweep_secs.{tag}"), dt);
        println!("== {name} ==  (sweep took {dt:.2}s)");
        let series: Vec<(&str, &Series)> =
            fig.time_days.iter().map(|(s, v)| (s.name(), v)).collect();
        println!("{}", ascii_plot(&series, 72, 18, "training time, days"));
        let series: Vec<(&str, &Series)> =
            fig.memory_gib.iter().map(|(s, v)| (s.name(), v)).collect();
        println!("{}", ascii_plot(&series, 72, 14, "GPU-resident memory, GiB"));
        for (s, v) in &fig.time_days {
            if let Some((x, t)) = v.last() {
                print!("  {}@X_{x}: {t:.1} d", s.name());
            }
        }
        println!("\n");

        // Shape check: improved beats baseline at the largest scale.
        let t = |strategy: Strategy| {
            fig.time_days
                .iter()
                .find(|(s, _)| *s == strategy)
                .and_then(|(_, v)| v.last().map(|&(_, t)| t))
                .unwrap_or(f64::NAN)
        };
        assert!(
            t(Strategy::Improved) <= t(Strategy::Baseline) * 1.02,
            "{name}: improved {:.1} vs baseline {:.1}",
            t(Strategy::Improved),
            t(Strategy::Baseline)
        );
        json.push(&format!("improved_days_at_max.{tag}"), t(Strategy::Improved));
    }
    json.finish();
}
