//! Perf bench: discrete-event simulator throughput (ops scheduled per
//! second) across schedule shapes — the §Perf L3 target is ≥ 1 M ops/s.
//! Run via `cargo bench --bench sim_engine`.

use std::time::Instant;

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::schedule::{modular_pipeline, one_f_one_b, standard_ga, ScheduleSpec};
use lga_mpp::sim::{simulate, CostTable};

fn main() {
    let cluster = ClusterSpec::reference();
    let cases: Vec<(&str, usize, usize, usize, bool)> = vec![
        ("small  (16L/4S/8mb)", 16, 4, 8, false),
        ("medium (64L/8S/16mb)", 64, 8, 16, false),
        ("x160   (160L/5S/32mb, part)", 160, 5, 32, true),
        ("deep   (256L/16S/64mb)", 256, 16, 64, false),
        ("wide-mb(64L/8S/256mb)", 64, 8, 256, false),
    ];
    println!("{:<30} {:>8} {:>10} {:>12}", "case", "ops", "ms", "Mops/s");
    let mut worst = f64::MAX;
    for (name, d_l, n_l, n_mu, part) in cases {
        let spec = ScheduleSpec { d_l, n_l, n_mu, partition: part, data_parallel: true };
        let cfg = TrainConfig {
            strategy: if part { Strategy::Improved } else { Strategy::Baseline },
            n_b: 8,
            n_l,
            n_a: 1,
            n_mu,
            b_mu: 1.0,
            offload: false,
            partition: part,
        };
        let costs = CostTable::new(&XModel::new(32).shape(), &cfg, &cluster);
        for (policy, sched) in [
            ("modular", modular_pipeline(&spec)),
            ("gpipe", standard_ga(&spec)),
            ("1f1b", one_f_one_b(&spec)),
        ] {
            let n_ops = sched.len();
            let mut best = f64::MAX;
            for _ in 0..5 {
                let t0 = Instant::now();
                std::hint::black_box(simulate(&sched, &costs).makespan);
                best = best.min(t0.elapsed().as_secs_f64());
            }
            let mops = n_ops as f64 / best / 1e6;
            worst = worst.min(mops);
            println!("{:<30} {:>8} {:>10.3} {:>12.2}  [{policy}]", name, n_ops, best * 1e3, mops);
        }
    }
    println!("\nworst-case throughput: {worst:.2} M ops/s (target >= 1.0)");
}
