//! Perf bench: discrete-event simulator throughput (ops scheduled per
//! second) across schedule shapes — the §Perf L3 target is ≥ 1 M ops/s.
//!
//! Since the dependency-graph refactor, `simulate()` = `lower()` (build
//! the `ScheduleProgram`) + `simulate_program()` (the O(V+E) event
//! loop). The headline column times the fused path for comparability
//! with the pre-refactor engine; the lower/exec columns show the split,
//! and the planner-scale row (d_l=128, n_l=32, n_mu=128) is the
//! acceptance config for simulate-in-the-loop planning.
//!
//! Run via `cargo bench --bench sim_engine`.

use std::time::Instant;

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::report::BenchJson;
use lga_mpp::schedule::{lower, modular_pipeline, one_f_one_b, standard_ga, ScheduleSpec};
use lga_mpp::sim::{simulate, simulate_program, CostTable};

fn best_of<F: FnMut() -> f64>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let mut json = BenchJson::new("sim_engine");
    let cluster = ClusterSpec::reference();
    let cases: Vec<(&str, usize, usize, usize, bool)> = vec![
        ("small  (16L/4S/8mb)", 16, 4, 8, false),
        ("medium (64L/8S/16mb)", 64, 8, 16, false),
        ("x160   (160L/5S/32mb, part)", 160, 5, 32, true),
        ("deep   (256L/16S/64mb)", 256, 16, 64, false),
        ("wide-mb(64L/8S/256mb)", 64, 8, 256, false),
        ("planner(128L/32S/128mb)", 128, 32, 128, false),
    ];
    println!(
        "{:<30} {:>8} {:>9} {:>9} {:>9} {:>10}",
        "case", "ops", "lower ms", "exec ms", "full ms", "Mops/s"
    );
    let mut worst = f64::MAX;
    for (name, d_l, n_l, n_mu, part) in cases {
        let spec = ScheduleSpec {
            d_l,
            n_l,
            n_mu,
            tp: 1,
            partition: part,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
        let cfg = TrainConfig {
            strategy: if part { Strategy::Improved } else { Strategy::Baseline },
            n_b: 8,
            n_l,
            n_a: 1,
            n_mu,
            b_mu: 1.0,
            offload: false,
            partition: part,
            zero: 0,
        };
        let costs = CostTable::new(&XModel::new(32).shape(), &cfg, &cluster);
        for (policy, sched) in [
            ("modular", modular_pipeline(&spec)),
            ("gpipe", standard_ga(&spec)),
            ("1f1b", one_f_one_b(&spec)),
        ] {
            let n_ops = sched.len();
            let lower_t = best_of(5, || lower(&sched).unwrap().len() as f64);
            let program = lower(&sched).unwrap();
            let exec_t = best_of(5, || simulate_program(&program, &costs).makespan);
            let full_t = best_of(5, || simulate(&sched, &costs).makespan);
            let mops = n_ops as f64 / full_t / 1e6;
            worst = worst.min(mops);
            json.push(&format!("mops.{policy}.{}L_{}S_{}mb", d_l, n_l, n_mu), mops);
            println!(
                "{:<30} {:>8} {:>9.3} {:>9.3} {:>9.3} {:>10.2}  [{policy}]",
                name,
                n_ops,
                lower_t * 1e3,
                exec_t * 1e3,
                full_t * 1e3,
                mops
            );
        }
    }
    println!("\nworst-case throughput: {worst:.2} M ops/s (target >= 1.0)");
    json.push("worst_mops_per_sec", worst);
    json.finish();
}
