//! Perf bench for the pruned/parallel planner (and the simulator's
//! allocation-free hot path).
//!
//! Headline: the Figure-4/5-scale sweep — `sweep_xs(160)` × 3 strategies
//! on the reference cluster — run twice: once through the retained
//! serial exhaustive reference (`search_fastest_exhaustive`, the
//! pre-refactor cost), once through the pruned + parallel
//! `search_fastest` fan-out. Target: ≥ 5× on a multi-core runner, with
//! *identical plans* (checked here, not just in the tests).
//!
//! Second act: `simulate_program` with `record_timeline: false` and a
//! reused `SimScratch` must allocate nothing after warmup — measured
//! with a counting global allocator, asserted to be exactly zero bytes.
//!
//! Results land in `BENCH_planner_search.json` (serial vs parallel =
//! the before/after entry). Run via `cargo bench --bench planner_search`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::{sweep_xs, XModel};
use lga_mpp::planner::{par_map, planner_threads, search_fastest, search_fastest_exhaustive};
use lga_mpp::report::{menu_for, BenchJson};
use lga_mpp::schedule::{lower, modular_pipeline, ScheduleSpec};
use lga_mpp::sim::{simulate_program_into, CostTable, SimOptions, SimScratch};

/// Counts every allocation so the hot-path audit can assert zero.
struct CountingAlloc;

static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn main() {
    let cluster = ClusterSpec::reference();
    let xs = sweep_xs(160);
    let mut json = BenchJson::new("planner_search");
    json.push("threads", planner_threads() as f64);
    json.push("sweep_points", (xs.len() * Strategy::ALL.len()) as f64);

    // ---- planner sweep: serial exhaustive baseline ("before") ----------
    let t0 = Instant::now();
    let mut baseline = Vec::new();
    for &s in &Strategy::ALL {
        for &x in &xs {
            baseline.push(search_fastest_exhaustive(&XModel::new(x), &cluster, s, menu_for(s)));
        }
    }
    let serial_secs = t0.elapsed().as_secs_f64();

    // ---- planner sweep: pruned + parallel ("after") ---------------------
    let tasks: Vec<(Strategy, usize)> =
        Strategy::ALL.iter().flat_map(|&s| xs.iter().map(move |&x| (s, x))).collect();
    let t0 = Instant::now();
    let fast = par_map(&tasks, |_, &(s, x)| search_fastest(&XModel::new(x), &cluster, s, menu_for(s)));
    let parallel_secs = t0.elapsed().as_secs_f64();

    // Parity at bench time: identical plans, point for point.
    let mut mismatches = 0usize;
    for (slow, quick) in baseline.iter().zip(&fast) {
        match (slow, quick) {
            (None, None) => {}
            (Some(a), Some(b)) if a.cfg == b.cfg => {}
            _ => mismatches += 1,
        }
    }
    let speedup = serial_secs / parallel_secs;
    println!("== planner sweep: sweep_xs(160) × 3 strategies, reference cluster ==");
    println!(
        "  serial exhaustive {serial_secs:.3} s | pruned+parallel {parallel_secs:.3} s | \
         speedup {speedup:.1}x on {} threads (target >= 5x on a multi-core runner)",
        planner_threads()
    );
    println!("  plan mismatches vs baseline: {mismatches} (must be 0)");
    assert_eq!(mismatches, 0, "optimised search diverged from the exhaustive reference");
    json.push("serial_exhaustive_secs", serial_secs);
    json.push("pruned_parallel_secs", parallel_secs);
    json.push("speedup", speedup);

    // ---- simulator hot path: zero allocations after warmup --------------
    let spec =
        ScheduleSpec {
            d_l: 128,
            n_l: 32,
            n_mu: 128,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
    let cfg = TrainConfig {
        strategy: Strategy::Baseline,
        n_b: 8,
        n_l: 32,
        n_a: 1,
        n_mu: 128,
        b_mu: 1.0,
        offload: false,
        partition: false,
        zero: 0,
    };
    let costs = CostTable::new(&XModel::new(32).shape(), &cfg, &cluster);
    let program = lower(&modular_pipeline(&spec)).expect("lowers");
    let opts = SimOptions { record_timeline: false };
    let mut scratch = SimScratch::new();
    for _ in 0..3 {
        let r = simulate_program_into(&program, &costs, opts, &mut scratch);
        scratch.recycle(r);
    }
    let bytes_before = ALLOC_BYTES.load(Ordering::Relaxed);
    let calls_before = ALLOC_CALLS.load(Ordering::Relaxed);
    let iters = 50u32;
    let t0 = Instant::now();
    let mut makespan = 0.0f64;
    for _ in 0..iters {
        let r = simulate_program_into(&program, &costs, opts, &mut scratch);
        makespan = r.makespan;
        scratch.recycle(r);
    }
    let sim_secs = t0.elapsed().as_secs_f64() / iters as f64;
    let bytes = ALLOC_BYTES.load(Ordering::Relaxed) - bytes_before;
    let calls = ALLOC_CALLS.load(Ordering::Relaxed) - calls_before;
    let mops = program.len() as f64 / sim_secs / 1e6;
    println!("\n== simulator hot path: planner config (128L/32S/128mb, timeline off) ==");
    println!(
        "  {} ops | {:.3} ms/run | {:.2} M ops/s | makespan {:.3} ms",
        program.len(),
        sim_secs * 1e3,
        mops,
        makespan * 1e3
    );
    println!("  heap after warmup: {bytes} bytes / {calls} allocations over {iters} runs (target 0)");
    assert_eq!(bytes, 0, "simulator hot path allocated after warmup");
    json.push("sim_ops", program.len() as f64);
    json.push("sim_mops_per_sec", mops);
    json.push("sim_makespan_secs", makespan);
    json.push("sim_alloc_bytes_after_warmup", bytes as f64);
    json.push("sim_allocs_after_warmup", calls as f64);

    json.finish();
}
