//! Bench + regeneration for Tables 6.1, 6.2 and 6.3 (run via
//! `cargo bench --bench tab61_configs`).
//!
//! Prints the same rows the paper reports, checks the headline shape
//! (Improved ≈ 2x faster at 3d; memory a tiny fraction of the GPU), and
//! times the planner paths (criterion is unavailable offline; timings use
//! a simple best-of-N harness).

use std::time::Instant;

use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::report;
use lga_mpp::report::BenchJson;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    let mut best = f64::MAX;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    println!("[bench] {name}: best of {iters} = {:.3} ms", best * 1e3);
    best
}

fn main() {
    let mut json = BenchJson::new("tab61_configs");
    let model = XModel::x160();
    let cluster = ClusterSpec::reference();

    let t61 = report::table61(&model, &cluster);
    let t62 = report::table62(&model, &cluster);
    println!("{t61}");
    println!("{t62}");
    let t63 = report::table63(&model, &cluster);
    println!("{t63}");

    // Headline shape checks (paper vs regenerated).
    let rows: Vec<&str> = t61.trim_end().lines().collect();
    let improved_3d = rows.last().unwrap();
    assert!(improved_3d.contains("38640"), "improved 3d GPU count: {improved_3d}");
    let base_3d = rows[rows.len() - 2];
    let days = |line: &str| -> f64 {
        line.split_whitespace().rev().nth(1).unwrap().parse().unwrap()
    };
    let speedup = days(base_3d) / days(improved_3d);
    println!("3d speedup improved vs baseline: {speedup:.2}x (paper: 13 d / 6.8 d = 1.9x)");
    assert!(speedup > 1.6);
    json.push("improved_vs_baseline_3d_speedup", speedup);

    let t61_secs = bench("table 6.1 (9 closed-form plans)", 20, || {
        std::hint::black_box(report::table61(&model, &cluster));
    });
    json.push("table61_best_secs", t61_secs);
    let t63_secs = bench("table 6.3 (7 constrained searches)", 3, || {
        std::hint::black_box(report::table63(&model, &cluster));
    });
    json.push("table63_best_secs", t63_secs);
    json.finish();
}
