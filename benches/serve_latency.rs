//! Perf bench: continuous-batching serving latency vs offered load.
//!
//! Replays seeded Poisson traces through the serving batcher
//! ([`lga_mpp::serve::run_trace`]) on a fixed `{stages, tp}` deployment
//! at a sweep of offered rates around the deployment's saturation
//! point, and records the p50/p99 time-to-first-token, per-token
//! latency and tokens/sec at each rate. Because wave latencies are
//! memoised simulations of the compiled prefill/decode schedules, the
//! replay itself is pure arithmetic — the bench also times it to keep
//! the batcher's own overhead honest (a thousand-request trace must
//! replay in well under a second).
//!
//! Acceptance: p99 TTFT is monotonically non-decreasing in offered
//! rate, and the saturated run's throughput is within 1% of the
//! decode-bound ceiling.
//!
//! Run via `cargo bench --bench serve_latency`.

use std::time::Instant;

use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::serve::{run_trace, ServeCosts, Trace};
use lga_mpp::report::BenchJson;

fn main() {
    let mut json = BenchJson::new("serve_latency");
    let shape = XModel::new(16).shape();
    let cluster = ClusterSpec::reference();
    let (stages, tp, max_batch) = (4usize, 1usize, 8usize);
    let (n_requests, prompt, decode) = (1000usize, 64usize, 16usize);

    // Saturation rate: one full batch of decode waves per wall-clock
    // second of wave time, requests/sec.
    let mut costs = ServeCosts::new(&shape, &cluster, stages, tp);
    let wave = costs.decode_latency(max_batch);
    let saturation = max_batch as f64 / (decode as f64 * wave);
    println!(
        "deployment stages {stages} x tp {tp}, cap {max_batch}: wave {:.3} ms, \
         saturation ~{saturation:.1} req/s\n",
        wave * 1e3
    );

    let mut last_p99 = 0.0f64;
    let mut saturated_tps = 0.0f64;
    for (i, mult) in [0.25f64, 0.5, 1.0, 2.0, 4.0].iter().enumerate() {
        let rate = saturation * mult;
        let trace = Trace::poisson(42, rate, n_requests, prompt, decode);
        let t0 = Instant::now();
        let r = run_trace(&shape, &cluster, stages, tp, max_batch, &trace)
            .expect("reference deployment must be feasible");
        let replay = t0.elapsed().as_secs_f64();
        println!(
            "rate {rate:>7.1} req/s ({mult:>4}x sat): ttft p50 {:>8.1} ms  p99 {:>8.1} ms  \
             token p99 {:>6.1} ms  {:>8.1} tok/s  (replayed {n_requests} requests in {:.1} ms)",
            r.ttft_p50 * 1e3,
            r.ttft_p99 * 1e3,
            r.token_p99 * 1e3,
            r.tokens_per_sec,
            replay * 1e3
        );
        assert_eq!(r.completed, n_requests, "the batcher may not drop requests");
        assert!(
            r.ttft_p99 >= last_p99 - 1e-9,
            "p99 TTFT must not improve as offered load grows: {} after {last_p99}",
            r.ttft_p99
        );
        assert!(replay < 1.0, "replaying {n_requests} requests took {replay:.2}s");
        last_p99 = r.ttft_p99;
        saturated_tps = r.tokens_per_sec;
        json.push(&format!("rate_{i}_req_per_sec"), rate);
        json.push(&format!("rate_{i}_ttft_p50_ms"), r.ttft_p50 * 1e3);
        json.push(&format!("rate_{i}_ttft_p99_ms"), r.ttft_p99 * 1e3);
        json.push(&format!("rate_{i}_token_p99_ms"), r.token_p99 * 1e3);
        json.push(&format!("rate_{i}_tokens_per_sec"), r.tokens_per_sec);
        json.push(&format!("rate_{i}_replay_secs"), replay);
    }

    // At 4x saturation the pipeline never starves: throughput must sit
    // on the decode-bound ceiling (every wave full, prefills amortised).
    let ceiling = max_batch as f64 / wave;
    println!(
        "\nsaturated throughput {saturated_tps:.1} tok/s vs decode-bound ceiling {ceiling:.1}"
    );
    json.push("decode_ceiling_tokens_per_sec", ceiling);
    json.finish();
    assert!(
        saturated_tps <= ceiling * 1.01,
        "throughput {saturated_tps:.1} cannot beat the decode-bound ceiling {ceiling:.1}"
    );
    assert!(
        saturated_tps >= ceiling * 0.5,
        "saturated throughput {saturated_tps:.1} too far under the ceiling {ceiling:.1} — \
         prefill is dominating a decode-bound workload"
    );
}
