//! Perf bench: whole-world static verification at planner scale.
//!
//! The planner's simulate-in-the-loop ranking now runs every candidate
//! through the static verifier ([`lga_mpp::planner::statically_valid`])
//! before paying for a simulation. That filter is only free if
//! verification is much cheaper than the simulation it gates — this
//! bench sweeps the same candidate set the planner enumerates at X_32
//! (~160 configurations across the three strategies) and times the
//! full verification pass (structural verdict via the lowering cache's
//! memo + per-candidate memory bound) against simulating the same
//! candidates.
//!
//! Acceptance: verification of the sweep must be at least 10x cheaper
//! than simulating it.
//!
//! Run via `cargo bench --bench analysis`.

use std::time::Instant;

use lga_mpp::costmodel::Strategy;
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::planner::{simulate_plan, statically_valid, Candidates, Plan};
use lga_mpp::report::{menu_for, BenchJson};

fn best_of<F: FnMut() -> f64>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let mut json = BenchJson::new("analysis");
    let model = XModel::new(32);
    let cluster = ClusterSpec::reference();

    // The planner's candidate sweep: every configuration the grid search
    // enumerates, built into full plans (fit-checked like the search).
    let mut plans: Vec<Plan> = Vec::new();
    for strategy in Strategy::ALL {
        for cfg in Candidates::new(&model, &cluster, strategy, menu_for(strategy)) {
            let plan = Plan::build_pub(&model, cfg, &cluster);
            if plan.fits_gpu(&cluster) {
                plans.push(plan);
            }
        }
    }
    println!("candidate sweep: {} plans at X_32\n", plans.len());

    // Warm pass doubles as correctness: every enumerated candidate must
    // verify (the filter may never shrink the search space).
    for plan in &plans {
        if let Err(e) = statically_valid(&model, &cluster, plan) {
            panic!("candidate {:?} rejected by the static verifier: {e}", plan.cfg);
        }
    }

    let verify_t = best_of(7, || {
        let mut ok = 0usize;
        for plan in &plans {
            if statically_valid(&model, &cluster, plan).is_ok() {
                ok += 1;
            }
        }
        ok as f64
    });
    let sim_t = best_of(3, || {
        let mut total = 0.0;
        for plan in &plans {
            total += simulate_plan(&model, &cluster, plan).secs_per_sequence;
        }
        total
    });

    let speedup = sim_t / verify_t;
    println!(
        "verify sweep:   {:>9.3} ms ({:>7.1} us/candidate)",
        verify_t * 1e3,
        verify_t * 1e6 / plans.len() as f64
    );
    println!(
        "simulate sweep: {:>9.3} ms ({:>7.1} us/candidate)",
        sim_t * 1e3,
        sim_t * 1e6 / plans.len() as f64
    );
    println!("\nverification is {speedup:.1}x cheaper than simulation (target: >= 10x)");

    json.push("candidates", plans.len() as f64);
    json.push("verify_sweep_secs", verify_t);
    json.push("simulate_sweep_secs", sim_t);
    json.push("speedup_vs_simulation", speedup);
    json.finish();

    assert!(
        speedup >= 10.0,
        "static verification must be >= 10x cheaper than simulation, got {speedup:.1}x"
    );
}
