//! Bench + regeneration for Figures 1, 2 and 3: simulate the four
//! scheduling policies, compare measured bubble/overlap against the
//! paper's closed forms, and time the simulator.
//! Run via `cargo bench --bench fig1_schedules`.

use std::time::Instant;

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::report::BenchJson;
use lga_mpp::schedule::{layered_ga, modular_pipeline, one_f_one_b, standard_ga, ScheduleSpec};
use lga_mpp::sim::{simulate, CostTable};

fn costs(n_b: usize, n_l: usize, n_mu: usize, partition: bool) -> CostTable {
    let cfg = TrainConfig {
        strategy: if partition { Strategy::Improved } else { Strategy::Baseline },
        n_b,
        n_l,
        n_a: 1,
        n_mu,
        b_mu: 1.0,
        offload: false,
        partition,
        zero: 0,
    };
    CostTable::new(&XModel::new(32).shape(), &cfg, &ClusterSpec::reference())
}

fn main() {
    let mut json = BenchJson::new("fig1_schedules");
    // --- Figure 1: reduction overlap ------------------------------------
    let spec = ScheduleSpec {
        d_l: 16,
        n_l: 1,
        n_mu: 8,
        tp: 1,
        partition: false,
        offload: false,
        data_parallel: true,
        zero: 0,
    };
    let c = costs(8, 1, 8, false);
    let rs = simulate(&standard_ga(&spec), &c);
    let rl = simulate(&layered_ga(&spec), &c);
    println!(
        "Figure 1 | exposed reduction tail: standard {:.3} ms, layered {:.3} ms; \
         makespan {:.3} vs {:.3} ms",
        rs.exposed_network_tail() * 1e3,
        rl.exposed_network_tail() * 1e3,
        rs.makespan * 1e3,
        rl.makespan * 1e3
    );
    assert!(rl.exposed_network_tail() < rs.exposed_network_tail() * 0.3);
    json.push("fig1_standard_tail_secs", rs.exposed_network_tail());
    json.push("fig1_layered_tail_secs", rl.exposed_network_tail());

    // --- Figure 2: partition traffic ------------------------------------
    let spec_p = ScheduleSpec {
        d_l: 16,
        n_l: 1,
        n_mu: 8,
        tp: 1,
        partition: true,
        offload: false,
        data_parallel: true,
        zero: 0,
    };
    let cp = costs(8, 1, 8, true);
    let s2 = standard_ga(&spec_p);
    let l2 = layered_ga(&spec_p);
    let restores = |s: &lga_mpp::schedule::Schedule| {
        s.count(|o| matches!(o, lga_mpp::schedule::Op::RestoreParams { .. }))
    };
    println!(
        "Figure 2 | restores: standard {} vs layered {} ({}x); makespan {:.3} vs {:.3} ms",
        restores(&s2),
        restores(&l2),
        restores(&s2) / restores(&l2),
        simulate(&s2, &cp).makespan * 1e3,
        simulate(&l2, &cp).makespan * 1e3
    );
    assert_eq!(restores(&s2), 8 * restores(&l2));

    // --- Figure 3: pipeline bubble --------------------------------------
    let spec3 = ScheduleSpec {
        d_l: 16,
        n_l: 4,
        n_mu: 8,
        tp: 1,
        partition: false,
        offload: false,
        data_parallel: false,
        zero: 0,
    };
    let c3 = costs(1, 4, 8, false);
    let rn = simulate(&standard_ga(&spec3), &c3);
    let rm = simulate(&modular_pipeline(&spec3), &c3);
    let rf = simulate(&one_f_one_b(&spec3), &c3);
    println!(
        "Figure 3 | bubble: contiguous {:.4} (closed form 0.375), modular {:.4} \
         (closed form 0.094), 1f1b {:.4}",
        rn.bubble_fraction(),
        rm.bubble_fraction(),
        rf.bubble_fraction()
    );
    assert!(rm.makespan < rn.makespan);

    // --- simulator timing ------------------------------------------------
    let big = ScheduleSpec {
        d_l: 160,
        n_l: 5,
        n_mu: 32,
        tp: 1,
        partition: true,
        offload: false,
        data_parallel: true,
        zero: 0,
    };
    let cb = costs(16, 5, 32, true);
    let sched = modular_pipeline(&big);
    let n_ops = sched.len();
    let mut best = f64::MAX;
    let mut big_makespan = 0.0f64;
    for _ in 0..5 {
        let t0 = Instant::now();
        let r = simulate(&sched, &cb);
        big_makespan = std::hint::black_box(r.makespan);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    println!(
        "[bench] simulate modular X_160-shape ({n_ops} ops): {:.3} ms ({:.2} M ops/s)",
        best * 1e3,
        n_ops as f64 / best / 1e6
    );
    json.push("fig3_modular_bubble", rm.bubble_fraction());
    json.push("sim_x160_mops_per_sec", n_ops as f64 / best / 1e6);
    json.push("sim_x160_makespan_secs", big_makespan);
    json.finish();
}
