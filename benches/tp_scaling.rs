//! Tensor-parallel scaling accounting: per-rank FLOPs, resident state
//! bytes and tp wire bytes vs the shard degree, from the shared shape
//! arithmetic (`TransformerShape::params_per_layer_shard` /
//! `m0_bytes_per_token_shard`) and the simulator's cost table. Asserts
//! the 1/tp slope sharded execution exists to buy: the per-rank matrix
//! state divides by tp (up to the replicated layernorm sliver) while the
//! all-reduce wire volume grows with the ring factor 2·(tp−1)/tp.
//! Run via `cargo bench --bench tp_scaling`; writes BENCH_tp_scaling.json.

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::report::BenchJson;
use lga_mpp::sim::CostTable;

fn main() {
    let mut json = BenchJson::new("tp_scaling");
    let cluster = ClusterSpec::reference();
    let model = XModel::new(64);
    let shape = model.shape();
    let (b_mu, d_s) = (1.0f64, shape.d_s as f64);

    println!("== tp scaling (X_64 layer, b_mu = 1) ==");
    println!(
        "{:>4} {:>16} {:>16} {:>16} {:>16}",
        "tp", "flops/layer-pass", "state B/rank", "m0 B/token", "tp wire B/pass"
    );

    let mut prev_state = f64::INFINITY;
    let mut baseline_state = 0.0f64;
    for tp in [1usize, 2, 4] {
        let cfg = TrainConfig {
            strategy: Strategy::Improved,
            n_b: 1,
            n_l: 1,
            n_a: tp,
            n_mu: 4,
            b_mu,
            offload: false,
            partition: false,
            zero: 0,
        };
        let costs = CostTable::new(&shape, &cfg, &cluster);

        // Per-rank compute of one layer pass (fwd + bwd incl. recompute):
        // 8 flops/token/param over the rank's 1/tp parameter shard.
        let flops = 8.0 * b_mu * d_s * shape.params_per_layer() / tp as f64;
        // Per-rank resident training state of one layer (fp32 params +
        // Adam moments, 12 B/param) — exact shard arithmetic, counting
        // the replicated layernorms/biases every rank keeps.
        let state = 12.0 * shape.params_per_layer_shard(tp);
        let m0 = shape.m0_bytes_per_token_shard(tp);
        // tp wire bytes of one layer pass, from the cost model's C.4.3
        // amortisation (0 at tp = 1).
        let wire = costs.wire.tp_all_reduce_fwd + costs.wire.tp_all_reduce_bwd;

        println!("{tp:>4} {flops:>16.3e} {state:>16.3e} {m0:>16.3e} {wire:>16.3e}");
        json.push(&format!("tp{tp}.flops_per_layer_pass"), flops);
        json.push(&format!("tp{tp}.state_bytes_per_rank"), state);
        json.push(&format!("tp{tp}.m0_bytes_per_token"), m0);
        json.push(&format!("tp{tp}.tp_wire_bytes_per_pass"), wire);

        if tp == 1 {
            baseline_state = state;
            assert_eq!(wire, 0.0, "tp = 1 moves no tensor-parallel bytes");
        } else {
            // The 1/tp memory slope: per-rank state is the full state
            // divided by tp, within the (tiny, matrix-dominated) sliver
            // of replicated layernorm parameters.
            let ratio = state * tp as f64 / baseline_state;
            assert!(
                (1.0..1.01).contains(&ratio),
                "tp={tp}: state slope off 1/tp (ratio {ratio:.5})"
            );
            assert!(wire > 0.0);
        }
        assert!(state < prev_state, "state must fall monotonically with tp");
        prev_state = state;
    }

    // The live-activation shard keeps the layer boundaries whole: the
    // m0 slope is strictly between 1 (no sharding) and 1/tp.
    let m0_1 = shape.m0_bytes_per_token_shard(1);
    let m0_4 = shape.m0_bytes_per_token_shard(4);
    assert!(m0_4 < m0_1 && m0_4 > m0_1 / 4.0);
    json.push("m0_shard_ratio_tp4", m0_4 / m0_1);

    json.finish();
}
