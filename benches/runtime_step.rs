//! Perf bench: real trainer step latency on the tiny preset, broken into
//! PJRT execute time vs coordination overhead — the §Perf L3 target is
//! PJRT-dominated steps (coordination < 10% once compute is non-trivial).
//! Run via `cargo bench --bench runtime_step` (needs `make artifacts`).

use lga_mpp::optim::LrSchedule;
use lga_mpp::report::BenchJson;
use lga_mpp::trainer::{train, Policy, TrainerConfig};

/// Returns the measured ms/step (None when artifacts are missing).
fn run(policy: Policy, n_b: usize, n_l: usize, n_mu: usize, partition: bool) -> Option<f64> {
    let mut cfg = TrainerConfig::quick("tiny");
    cfg.steps = 10;
    cfg.n_b = n_b;
    cfg.n_l = n_l;
    cfg.n_mu = n_mu;
    cfg.policy = policy;
    cfg.partition = partition;
    cfg.lr = LrSchedule::constant(1e-3);
    match train(&cfg) {
        Ok(r) => {
            let workers = (n_b * n_l) as f64;
            let step_ms = r.wall_secs / cfg.steps as f64 * 1e3;
            let exec_frac = r.execute_secs / (r.wall_secs * workers);
            println!(
                "{:<9} dp={n_b} pp={n_l} mb={n_mu} part={partition:<5} | {:>8.2} ms/step | \
                 PJRT {:>5.1}% of worker time | {:>6} calls | {:>6.2} M coll elems",
                policy.name(),
                step_ms,
                exec_frac * 100.0,
                r.execute_calls,
                r.collective_elems_sent as f64 / 1e6,
            );
            Some(step_ms)
        }
        Err(e) => {
            println!("skipped ({e:#})");
            None
        }
    }
}

fn main() {
    let mut json = BenchJson::new("runtime_step");
    if !TrainerConfig::quick("tiny").artifacts_root.join("tiny/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        json.push("skipped_missing_artifacts", 1.0);
        json.finish();
        return;
    }
    println!("== trainer step latency (tiny preset, 10-step runs) ==");
    let cases: [(Policy, usize, usize, usize, bool); 8] = [
        (Policy::Improved, 1, 1, 2, false),
        (Policy::Baseline, 1, 1, 2, false),
        (Policy::Improved, 2, 1, 4, false),
        (Policy::Improved, 2, 1, 4, true),
        (Policy::Baseline, 2, 1, 4, true),
        (Policy::Improved, 2, 2, 4, true),
        (Policy::Baseline, 2, 2, 4, false),
        (Policy::OneFOneB, 2, 2, 4, false),
    ];
    for (policy, n_b, n_l, n_mu, partition) in cases {
        let key = format!("step_ms.{}.dp{n_b}_pp{n_l}_mb{n_mu}_part{partition}", policy.name());
        let step_ms = run(policy, n_b, n_l, n_mu, partition);
        json.push(&key, step_ms.unwrap_or(f64::NAN));
    }
    json.finish();
}
