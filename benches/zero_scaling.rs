//! ZeRO state-sharding scaling accounting: per-device training state
//! and dp wire bytes vs the data-parallel degree, per stage, from the
//! shared memory model (`MemoryBreakdown`) and the simulator's cost
//! table. Asserts the 1/dp optimizer-state slope the sharding exists to
//! buy: stages 1–2 shard the 8 B/param Adam moments across the dp
//! group, stage 3 shards all 12 B/param, while the reduce-scatter +
//! all-gather wire volume stays exactly the all-reduce's for stage 2.
//! Run via `cargo bench --bench zero_scaling`; writes
//! BENCH_zero_scaling.json.

use lga_mpp::costmodel::{MemoryBreakdown, Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::report::BenchJson;
use lga_mpp::sim::CostTable;

fn cfg(n_b: usize, zero: u8) -> TrainConfig {
    TrainConfig {
        strategy: Strategy::Improved,
        n_b,
        n_l: 1,
        n_a: 1,
        n_mu: 4,
        b_mu: 1.0,
        offload: false,
        partition: false,
        zero,
    }
}

fn main() {
    let mut json = BenchJson::new("zero_scaling");
    let cluster = ClusterSpec::reference();
    let model = XModel::new(64);
    let shape = model.shape();
    let p = shape.params();

    println!("== zero scaling (X_64, single stage, b_mu = 1) ==");
    println!(
        "{:>4} {:>5} {:>16} {:>16} {:>16}",
        "dp", "zero", "state B/device", "dp wire B/layer", "vs all-reduce"
    );

    for dp in [2usize, 4, 8] {
        let full = MemoryBreakdown::evaluate(&shape, &cfg(dp, 0)).state;
        let all_reduce =
            CostTable::new(&shape, &cfg(dp, 0), &cluster).wire.reduce_grad;
        assert!((full - 12.0 * p).abs() < 1e-3, "zero=0 state is 12 B/param");

        for zero in [1u8, 2, 3] {
            let c = cfg(dp, zero);
            let state = MemoryBreakdown::evaluate(&shape, &c).state;
            let wire = CostTable::new(&shape, &c, &cluster).wire;
            let zero_wire = wire.reduce_scatter_grad + wire.all_gather_params;

            // The slope the sharding buys: the sharded fraction of the
            // 12 B/param divides exactly by dp.
            let want = match zero {
                1 | 2 => (4.0 + 8.0 / dp as f64) * p,
                _ => 12.0 / dp as f64 * p,
            };
            assert!(
                (state / want - 1.0).abs() < 1e-9,
                "dp={dp} zero={zero}: state {state:.3e} vs 1/dp law {want:.3e}"
            );
            assert!(state < full, "sharded state must shrink");

            // Stage 2's reduce-scatter + all-gather move exactly the
            // bytes the all-reduce they replace would have moved (each
            // half is half the ring volume).
            let vs = if zero >= 2 { zero_wire / all_reduce } else { f64::NAN };
            if zero >= 2 {
                assert!(
                    (vs - 1.0).abs() < 1e-9,
                    "dp={dp} zero={zero}: stage-2 volume {zero_wire:.3e} \
                     vs all-reduce {all_reduce:.3e}"
                );
            }

            println!("{dp:>4} {zero:>5} {state:>16.3e} {zero_wire:>16.3e} {vs:>16.3}");
            json.push(&format!("dp{dp}.zero{zero}.state_bytes_per_device"), state);
            json.push(&format!("dp{dp}.zero{zero}.dp_wire_bytes_per_layer"), zero_wire);
        }

        // Cross-stage ordering at this dp: stage 3 ≤ stages 1–2 < full.
        let s12 = MemoryBreakdown::evaluate(&shape, &cfg(dp, 2)).state;
        let s3 = MemoryBreakdown::evaluate(&shape, &cfg(dp, 3)).state;
        assert!(s3 < s12 && s12 < full);
        json.push(&format!("dp{dp}.state_ratio_zero2"), s12 / full);
        json.push(&format!("dp{dp}.state_ratio_zero3"), s3 / full);
    }

    json.finish();
}
